"""Threaded Worker runtime — the WRM of paper Fig 5, executing for real.

A Worker is a multi-thread process.  One lane thread per compute device
(CPU core / accelerator); every lane pulls ``(data chunk, operation)``
tuples from the shared :class:`~repro.core.scheduling.ReadyScheduler`
under the configured policy and executes the operation's *function
variant* for its device kind.

Accelerator lanes model the discrete-memory hierarchy of the paper:
inputs are *uploaded* into a per-lane :class:`DeviceMemory` (LRU),
outputs are *downloaded* back to host memory unless the data-locality
scheduler keeps them resident for a dependent operation, and with
``prefetch=True`` the upload of the next selected tuple overlaps the
ongoing computation via a per-lane copy thread (§IV-D's
upload/process/download pipeline).

Two device-resident fast paths extend the basic model:

* ``chaining=True`` — when consecutive ops of one pipeline instance
  land on the same accelerator lane (DL reuse), the intermediate state
  stays in that lane's DeviceMemory and the host write-back is
  *deferred*: a chained output only materializes to the host tier when
  a host-side consumer (sibling lane, stage-completion read, Manager
  pull) actually needs the bytes, or when the device LRU spills it.
  Host lanes get the same dependent-affinity: a CPU-resident chain's
  intermediates skip the region-store round-trip and are served by
  reference until stage completion (``host_chain_*`` stats).
* ``micro_batch=B`` — an idle accelerator lane pops up to ``B`` ready
  instances of the same *batchable* op (``FunctionVariant.batchable``)
  and executes them as one batched call, amortizing per-op dispatch
  and launch overheads over the batch.

On a single-process deployment (this container) lanes are plain
threads; on a hybrid cluster the same class drives host cores plus one
control thread per accelerator — the WCC/Manager protocol is identical
(``core/manager.py``) and crosses process boundaries through a
:mod:`repro.transport` ``WorkerClient`` (``submit/forward/pull`` RPCs
in, ``complete/heartbeat/drop`` notifies out).
"""

from __future__ import annotations

import queue
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .cost_model import TPU_V5E, op_cost_from_seconds, optimal_micro_batch
from .scheduling import HOST_KIND, ReadyScheduler
from .variants import VariantRegistry, registry as global_registry
from .workflow import OperationInstance, StageInstance
from ..staging import RegionStore, StagingAgent, StagingConfig, op_key
from ..staging.tiers import HostTier
from ..telemetry.metrics import MetricsRegistry
from ..telemetry.tracing import SpanContext, current_context, use_context

__all__ = ["DeviceMemory", "LaneSpec", "OpContext", "WorkerRuntime"]


class DeviceMemory:
    """LRU store emulating an accelerator's discrete memory.

    ``put`` returns the entries it evicted (oldest-first, never the
    entry just inserted) so the owner can write device-only values back
    to the host tier instead of losing them — slot budgets stay a soft
    cap under device-resident chaining, never a correctness hazard.
    """

    def __init__(self, slots: int = 64):
        self.slots = slots
        self._store: "OrderedDict[int, Any]" = OrderedDict()
        self.uploads = 0
        self.downloads = 0
        self.evictions = 0

    def put(self, uid: int, value: Any) -> list[tuple[int, Any]]:
        self._store[uid] = value
        self._store.move_to_end(uid)
        evicted: list[tuple[int, Any]] = []
        while len(self._store) > self.slots:
            victim = next(k for k in self._store if k != uid)
            evicted.append((victim, self._store.pop(victim)))
            self.evictions += 1
        return evicted

    def get(self, uid: int) -> Any:
        value = self._store[uid]
        self._store.move_to_end(uid)
        return value

    def __contains__(self, uid: int) -> bool:
        return uid in self._store

    def resident_uids(self) -> set[int]:
        return set(self._store)


@dataclass(frozen=True)
class LaneSpec:
    kind: str = HOST_KIND
    index: int = 0
    memory_slots: int = 64


@dataclass
class OpContext:
    """What an operation implementation receives."""

    chunk: Any                       # DataChunk (payload = tile, request, ...)
    inputs: dict[str, Any]           # dep op name -> output value
    lane_kind: str = HOST_KIND

    def sole_input(self) -> Any:
        if len(self.inputs) == 1:
            return next(iter(self.inputs.values()))
        if not self.inputs:
            return self.chunk.payload
        raise ValueError(f"expected one input, have {sorted(self.inputs)}")


@dataclass
class _LaneState:
    spec: LaneSpec
    thread: Optional[threading.Thread] = None
    memory: Optional[DeviceMemory] = None
    busy_seconds: float = 0.0
    executed: int = 0
    busy: bool = False  # currently executing (work-conserving batching)
    # Prefetch double-buffer: next tuple whose inputs are being uploaded.
    staged: "queue.Queue[tuple[OperationInstance, threading.Event]]" = field(
        default_factory=lambda: queue.Queue(maxsize=1)
    )


class WorkerRuntime:
    """Executes stage instances over heterogeneous lanes."""

    def __init__(
        self,
        worker_id: int = 0,
        lanes: tuple[LaneSpec, ...] = (LaneSpec(HOST_KIND, 0),),
        *,
        policy: str = "fcfs",
        locality: bool = False,
        prefetch: bool = False,
        chaining: bool = False,
        micro_batch: int = 1,
        batch_budget: float | None = None,
        speedups_known: bool = True,
        staging: StagingConfig | None = None,
        variant_registry: VariantRegistry | None = None,
        on_stage_complete: Callable[..., None] | None = None,
        observe_runtimes: bool = True,
        on_heartbeat=None,
        registry: MetricsRegistry | None = None,
        tracer=None,
        recorder=None,
    ) -> None:
        self.worker_id = worker_id
        self.on_heartbeat = on_heartbeat
        self.registry = variant_registry or global_registry
        # One metrics registry per worker process: the scheduler, region
        # store, staging agent, and this runtime's own counters all
        # register into it, so ``stats()`` (and the ``get_stats`` RPC)
        # are thin views over a single place.
        self.metrics = registry or MetricsRegistry(f"worker{worker_id}")
        self.tracer = tracer          # telemetry.Tracer (optional)
        self.recorder = recorder      # telemetry.FlightRecorder (optional)
        # Device-resident chaining needs the DL pop (residency-aware) to
        # actually route dependents onto the holding lane.
        self.chaining = chaining
        self.locality = locality or chaining
        self.micro_batch = max(int(micro_batch), 1)
        # Adaptive micro-batch sizing: with a latency budget (seconds
        # one batched launch may take), per-op batch depth comes from
        # cost_model.optimal_micro_batch over the variant's observed
        # runtime instead of the static max_batch cap.
        self.batch_budget = batch_budget
        self.scheduler = ReadyScheduler(
            policy=policy,
            locality=self.locality,
            speedups_known=speedups_known,
            chain_affinity=1.0 if chaining else 0.0,
            registry=self.metrics,
        )
        self.prefetch = prefetch
        self.observe_runtimes = observe_runtimes
        self.on_stage_complete = on_stage_complete

        self._lanes = [
            _LaneState(
                spec=s,
                memory=DeviceMemory(s.memory_slots) if s.kind != HOST_KIND else None,
            )
            for s in lanes
        ]
        self._lock = threading.RLock()
        self._work_ready = threading.Condition(self._lock)
        self._stop = False
        self._failed = False

        # Hierarchical region store: the host tier replaces the old
        # ad-hoc output dict; disk/global tiers come from ``staging``.
        self.staging = staging
        self.store: RegionStore = (
            staging.build_store(registry=self.metrics)
            if staging is not None
            else RegionStore([HostTier()], registry=self.metrics)
        )
        # Cross-worker pull hooks, wired by the Manager (direct mode) or
        # a transport WorkerClient (bus mode).  ``fetch_regions`` is the
        # batched flavor: ordered keys in, same-length values out, one
        # round-trip for the lot.
        self.fetch_region: Callable[[Any], Any] | None = None
        self.fetch_regions: Callable[[list], list] | None = None
        self.agent: StagingAgent | None = None
        if staging is not None and staging.prefetch:
            self.agent = StagingAgent(
                self.store,
                worker_id=worker_id,
                fetch=self._fetch_region,
                fetch_batch=self._fetch_regions,
                on_staged=self._input_staged,
                watermark=staging.watermark,
                registry=self.metrics,
            )

        # Execution state.  ``_op_claimed`` marks ops a lane has popped
        # for execution: a revoked cancellation re-pushes its ops, and
        # the claim keeps the stale queue entry from running the op a
        # second time on another lane.
        self._op_done: set[int] = set()
        self._cancelled: set[int] = set()
        self._op_claimed: set[int] = set()
        self._stages: dict[int, StageInstance] = {}
        self.completion_order: list[int] = []
        self.errors: list[tuple[int, BaseException]] = []
        # Failure reporting: a stage whose op raised is reported upstream
        # exactly once (remaining ops cancelled), via the same callback
        # seam as completions.  ``on_op_start`` is a generic
        # instrumentation hook called as ``hook(runtime, op_instance)``
        # right before an op executes; raising from it routes into the
        # normal per-op failure path (fault harnesses plug in here — no
        # production code branches on "testing").
        self.on_stage_failed: Callable[[StageInstance, str], None] | None = None
        self.on_op_start: (
            Callable[["WorkerRuntime", OperationInstance], None] | None
        ) = None
        self._failed_stages: set[int] = set()
        # Device-resident chaining: op uid -> lane whose DeviceMemory
        # holds the *only* copy of its output (host write-back deferred
        # until a host-side consumer actually needs the bytes).
        self._device_only: dict[int, _LaneState] = {}
        c = lambda name: self.metrics.counter(f"worker.{name}")  # noqa: E731
        self.chain_hits = c("chain_hits")              # inputs served device-resident
        self.chain_deferred = c("chain_deferred")      # host copies skipped
        self.chain_writebacks = c("chain_writebacks")  # lazy downloads forced
        # Host-lane chaining: a CPU-produced intermediate whose consumers
        # are all known locally skips the region-store round-trip (lock +
        # tier accounting + pin/unpin churn) and is served by reference.
        self._host_chained: dict[int, Any] = {}
        self.host_chain_hits = c("host_chain_hits")             # served by reference
        self.host_chain_deferred = c("host_chain_deferred")     # store puts skipped
        self.host_chain_writebacks = c("host_chain_writebacks") # puts forced after all
        # Last speedup estimate a queue reorder was based on, per
        # variant: reestimate (O(queue)) only runs when the online EMA
        # actually moved an estimate, not on every completion.
        self._reorder_est: dict[str, float] = {}
        # Coordinator-bypass data plane: regions pushed here by siblings
        # (predictive push of sink outputs) before the lease's own pull.
        self.push_ingested = c("push_ingested")
        self.push_ingested_bytes = c("push_ingested_bytes")
        # Trace context per leased stage: captured at submit time (the
        # TracingBus installs the sender's context around the handler)
        # and re-installed around op execution and the completion
        # callback, so a request's spans chain across the lane threads.
        self._stage_ctx: dict[int, SpanContext] = {}
        # Async-pull attribution: region key -> (ctx, perf t0, wall t0)
        # seeded when a traced lease requests prefetch, consumed when
        # the StagingAgent lands the region — the pull's true latency
        # shows up as a ``region:pull`` span on the request's trace
        # even though the transfer ran on the agent thread.
        self._pull_ctx: dict[Any, tuple[SpanContext, float, float]] = {}
        # Gray-failure signals (PR 9): per-worker op-runtime and
        # region-pull-latency distributions in the shared registry.
        # Unlike the tracer-gated _pull_ctx above, _pull_t0 is always
        # on — the health plane must see latency whether or not the
        # request was sampled (same 4096-entry bound).
        self.op_runtime_hist = self.metrics.histogram("worker.op_runtime_s")
        self.pull_latency_hist = self.metrics.histogram(
            "worker.pull_latency_s"
        )
        self._pull_t0: dict[Any, float] = {}
        # Per-stage *execution* seconds (sum of its ops' lane time,
        # queueing excluded) — reported with the completion so the
        # Manager's health ratio is not confounded by queue depth: a
        # probe lease on an empty queue and a lease behind a full
        # window must be judged on the same signal.
        self._stage_exec: dict[int, float] = {}

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        if self.agent is not None:
            self.agent.start()
        for lane in self._lanes:
            t = threading.Thread(
                target=self._lane_loop, args=(lane,), daemon=True,
                name=f"worker{self.worker_id}-{lane.spec.kind}{lane.spec.index}",
            )
            lane.thread = t
            t.start()

    def stop(self) -> None:
        with self._lock:
            self._stop = True
            self._work_ready.notify_all()
        for lane in self._lanes:
            if lane.thread is not None:
                lane.thread.join(timeout=5.0)
        if self.agent is not None:
            self.agent.stop()

    def kill(self) -> None:
        """Simulate a node failure: lanes stop, state is lost."""
        with self._lock:
            self._failed = True
            self._stop = True
            self._work_ready.notify_all()
        if self.recorder is not None:
            # Postmortem: freeze the last N spans/events before the
            # process (or harness) tears the worker down.
            self.recorder.dump(
                "worker_crash", detail={"worker_id": self.worker_id}
            )
        if self.agent is not None:
            # A dead node must not keep pulling regions or mutating
            # execution state behind the Manager's back.
            self.agent.stop()

    @property
    def alive(self) -> bool:
        return not self._failed

    # -- submission -----------------------------------------------------------

    def submit_stage(self, si: StageInstance) -> None:
        """Lease received from the Manager: export fine-grain ops.

        Idempotent per stage instance: a re-lease of a stage this
        worker already holds (heartbeat-slander rejoin re-dispatches
        recovered leases) must not push duplicate op instances next to
        the queued/in-flight originals.
        """
        ctx = current_context()
        with self._lock:
            known = si.uid in self._stages
            self._stages[si.uid] = si
            if ctx is not None and ctx.sampled:
                sctx = self._stage_ctx.setdefault(si.uid, ctx)
                # Tag each op with its stage's context here, under the
                # lock, so the lane thread can read it without taking
                # the (contended) worker lock on the batch hot path.
                for oi in si.op_instances:
                    oi._trace_ctx = sctx  # type: ignore[attr-defined]
            local = {o.uid for o in si.op_instances}
            revoked = [
                oi for oi in si.op_instances if oi.uid in self._cancelled
            ]
            if revoked:
                # A re-lease of a stage this worker cancelled earlier
                # (probation entry or a drain re-queued it, and the
                # Manager handed it back — e.g. as a probe lease): the
                # cancellation is revoked and the ops requeue, else the
                # lease wedges with idle lanes until a hedge covers it.
                for oi in revoked:
                    self._cancelled.discard(oi.uid)
                    self._op_claimed.discard(oi.uid)
                    self._maybe_estimate(oi)
                    if (
                        oi.deps.issubset(self._op_done)
                        and oi.uid not in self._op_done
                    ):
                        self.scheduler.push(oi)
            if not known:
                for oi in si.op_instances:
                    self._maybe_estimate(oi)
                    if oi.deps.issubset(self._op_done) and oi.uid not in self._op_done:
                        self.scheduler.push(oi)
            self._work_ready.notify_all()
            missing = [
                op_key(dep)
                for oi in si.op_instances
                for dep in oi.deps
                if dep not in self._op_done and dep not in local
            ]
            if (
                missing
                and ctx is not None
                and ctx.sampled
                and self.tracer is not None
                and len(self._pull_ctx) < 4096
            ):
                now_p, now_w = time.perf_counter(), time.time()
                for key in missing:
                    self._pull_ctx.setdefault(key, (ctx, now_p, now_w))
            if missing and len(self._pull_t0) < 4096:
                t0 = time.perf_counter()
                for key in missing:
                    self._pull_t0.setdefault(key, t0)
        # Leased but not started: ask the staging agent to pull the
        # cross-stage inputs into the host tier ahead of execution.
        if self.agent is not None and missing:
            self.agent.request_prefetch(missing)

    def provide_input(self, uid: int, value: Any) -> None:
        """Host-side injection of upstream outputs (cross-worker flow)."""
        with self._lock:
            self.store.put(op_key(uid), value)
            self._op_done.add(uid)

    def forward_inputs(
        self, items: list[tuple]
    ) -> list[int]:
        """Batched input delivery: one control-plane round-trip for a
        whole lease's cross-stage inputs.

        Each item is ``(uid, value, push[, inbound])``: inputs already
        staged here are marked available (returned, so the Manager can
        account the bytes it did not re-send); the rest are injected
        when ``push`` is set, or left for the StagingAgent to pull when
        not.  ``inbound`` flags a key the Manager predicted a sibling
        will *push* here — the agent defers its pull for a grace period
        so the push and the prefetch don't cross the wire twice.
        """
        staged: list[int] = []
        expected: list[Any] = []
        for item in items:
            uid, value, push = item[0], item[1], item[2]
            inbound = bool(item[3]) if len(item) > 3 else False
            if self.mark_staged_input(uid):
                staged.append(uid)
            elif push:
                self.provide_input(uid, value)
            elif inbound:
                expected.append(op_key(uid))
        if expected and self.agent is not None:
            self.agent.expect_push(expected)
        return staged

    def ingest_push(self, key: Any, value: Any) -> int:
        """A sibling pushed a predicted input (data plane, coordinator
        bypassed): land it in the host tier and unlock any waiting ops.
        Returns the bytes landed (0 = rejected)."""
        if value is None:
            return 0
        nbytes = self.store.put(key, value)
        if isinstance(key, tuple) and len(key) == 2 and key[0] == "op":
            with self._lock:
                uid = key[1]
                if uid not in self._op_done:
                    self._op_done.add(uid)
                    self._release_dependents_locked(uid)
        self.push_ingested += 1
        self.push_ingested_bytes += nbytes
        return nbytes

    def invalidate_region(self, key: Any, worker_id: int | None = None) -> None:
        """Manager broadcast: ``worker_id`` no longer holds ``key`` —
        keep the staging agent's holder cache honest."""
        if self.agent is not None:
            self.agent.invalidate_holder(key, worker_id)

    def has_region(self, key: Any) -> bool:
        """True when ``key`` is resident in any tier of this worker
        (including device-only / host-chained outputs)."""
        if key in self.store:
            return True
        if isinstance(key, tuple) and len(key) == 2 and key[0] == "op":
            with self._lock:
                return key[1] in self._device_only or key[1] in self._host_chained
        return False

    def pull_region(self, key: Any) -> Any:
        """Serve a region to a remote peer (Manager failover refetch /
        directory-routed pull), materializing chained outputs."""
        with self._lock:
            value = self.store.get(key)
            if value is None and isinstance(key, tuple) and len(key) == 2 \
                    and key[0] == "op":
                value = self._materialize_locked(key[1])
            return value

    def mark_staged_input(self, uid: int) -> bool:
        """Skip-copy path: if op ``uid``'s output is already resident in
        a tier here, mark it available (and unlock waiting ops) so the
        Manager need not re-send the bytes.  False => caller must
        ``provide_input``."""
        with self._lock:
            if (
                op_key(uid) not in self.store
                and uid not in self._device_only
                and uid not in self._host_chained
            ):
                return False
            if uid not in self._op_done:
                self._op_done.add(uid)
                self._release_dependents_locked(uid)
            return True

    def _fetch_region(self, key: Any) -> Any:
        fetch = self.fetch_region
        return fetch(key) if fetch is not None else None

    def _fetch_regions(self, keys: list) -> Optional[list]:
        """Batched pull used by the StagingAgent; None => unwired, the
        agent falls back to per-key ``fetch`` round-trips."""
        fetch = self.fetch_regions
        return fetch(list(keys)) if fetch is not None else None

    def _input_staged(self, key: Any, nbytes: int = 0) -> None:
        """StagingAgent landed/promoted a region: unlock waiting ops."""
        if not (isinstance(key, tuple) and len(key) == 2 and key[0] == "op"):
            return
        uid = key[1]
        with self._lock:
            pulled = self._pull_ctx.pop(key, None)
            pull_t0 = self._pull_t0.pop(key, None)
            if uid in self._op_done:
                pulled = None  # duplicate landing: already accounted
                pull_t0 = None
            else:
                self._op_done.add(uid)
                self._release_dependents_locked(uid)
        if pull_t0 is not None:
            self.pull_latency_hist.observe(time.perf_counter() - pull_t0)
        if pulled is not None and self.tracer is not None:
            ctx, t0_perf, t0_wall = pulled
            sub = self.tracer.child(ctx)
            self.tracer.record_span(
                "region:pull",
                ctx=sub,
                parent=ctx.span_id,
                cat="region",
                ts=t0_wall,
                dur=time.perf_counter() - t0_perf,
                tid="staging",
                args={"key": uid, "bytes": int(nbytes)},
            )

    def _release_dependents_locked(self, produced_uid: int) -> None:
        for s in self._stages.values():
            for d in s.op_instances:
                if (
                    produced_uid in d.deps
                    and d.deps.issubset(self._op_done)
                    and d.uid not in self._op_done
                    and d.uid not in self._cancelled
                ):
                    self._maybe_estimate(d)
                    self.scheduler.push(d)
        self._work_ready.notify_all()

    def cancel_stage(self, si_uid: int) -> None:
        with self._lock:
            si = self._stages.get(si_uid)
            if si is None:
                return
            for oi in si.op_instances:
                if oi.uid not in self._op_done:
                    self._cancelled.add(oi.uid)
            self._stage_exec.pop(si_uid, None)

    def _accel_kind(self) -> str:
        accel_kinds = {l.spec.kind for l in self._lanes} - {HOST_KIND}
        return next(iter(accel_kinds)) if accel_kinds else HOST_KIND

    def _maybe_estimate(self, oi: OperationInstance) -> None:
        try:
            var = self.registry.get(oi.op.variant_name)
        except KeyError:
            return
        oi.speedup = var.estimate_speedup(self._accel_kind(), oi.chunk.meta)
        oi.transfer_impact = var.transfer_impact

    def _estimate_of(self, oi: OperationInstance) -> float:
        """Current speedup estimate (for ReadyScheduler.reestimate)."""
        try:
            var = self.registry.get(oi.op.variant_name)
        except KeyError:
            return oi.speedup
        return var.estimate_speedup(self._accel_kind(), oi.chunk.meta)

    # -- idle / completion tracking -----------------------------------------

    def drain(self, timeout: float = 60.0) -> bool:
        """Block until all submitted work completed (or timeout)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                pending = any(
                    oi.uid not in self._op_done and oi.uid not in self._cancelled
                    for si in self._stages.values()
                    for oi in si.op_instances
                )
                if self.errors:
                    return False
                if not pending:
                    return True
            time.sleep(0.002)
        return False

    def stats(self) -> dict[str, Any]:
        return {
            "profile": self.scheduler.stats.profile(),
            "reuse_hits": int(self.scheduler.stats.reuse_hits),
            "reuse_misses": int(self.scheduler.stats.reuse_misses),
            "lane_busy": {
                f"{l.spec.kind}{l.spec.index}": l.busy_seconds for l in self._lanes
            },
            "executed": sum(l.executed for l in self._lanes),
            "uploads": sum(
                l.memory.uploads for l in self._lanes if l.memory is not None
            ),
            "downloads": sum(
                l.memory.downloads for l in self._lanes if l.memory is not None
            ),
            "device_evictions": sum(
                l.memory.evictions for l in self._lanes if l.memory is not None
            ),
            "chain_hits": int(self.chain_hits),
            "chain_deferred": int(self.chain_deferred),
            "chain_writebacks": int(self.chain_writebacks),
            "host_chain_hits": int(self.host_chain_hits),
            "host_chain_deferred": int(self.host_chain_deferred),
            "host_chain_writebacks": int(self.host_chain_writebacks),
            "batches": int(self.scheduler.stats.batches),
            "batched_ops": int(self.scheduler.stats.batched_ops),
            "push_ingested": int(self.push_ingested),
            "push_ingested_bytes": int(self.push_ingested_bytes),
            "staging": self.store.stats(),
            "prefetch": self.agent.stats() if self.agent is not None else {},
        }

    def output_of(self, oi_uid: int) -> Any:
        with self._lock:
            value = self.store.get(op_key(oi_uid))
            if value is None:
                value = self._materialize_locked(oi_uid)
            return value

    # -- lane main loop -----------------------------------------------------------

    def _lane_loop(self, lane: _LaneState) -> None:
        while True:
            with self._lock:
                lane.busy = False
                while not self._stop and not self.scheduler:
                    self._work_ready.wait(timeout=0.25)
                if self._stop:
                    return
                resident = (
                    lane.memory.resident_uids()
                    if lane.memory is not None and self.locality
                    else None
                )
                if self.micro_batch > 1 and lane.memory is not None:
                    idle = sum(
                        1
                        for l in self._lanes
                        if l.memory is not None and not l.busy
                    )
                    limit = self.scheduler.batch_limit(self.micro_batch, idle)
                    ois = self.scheduler.pop_batch(
                        lane.spec.kind,
                        resident,
                        limit=limit,
                        batchable=self._batch_limit,
                    )
                else:
                    oi = self.scheduler.pop(lane.spec.kind, resident)
                    ois = [oi] if oi is not None else []
                ois = [
                    oi
                    for oi in ois
                    if oi is not None
                    and oi.uid not in self._cancelled
                    and oi.uid not in self._op_done
                    and oi.uid not in self._op_claimed
                ]
                for oi in ois:
                    self._op_claimed.add(oi.uid)
                if ois:
                    lane.busy = True
            if not ois:
                continue
            try:
                self._run_batch(lane, ois)
            except BaseException as exc:  # noqa: BLE001 - recorded, not raised
                self._record_failures([(oi, exc) for oi in ois])

    def _batch_limit(self, oi: OperationInstance) -> int:
        """pop_batch cap: the variant's declared max batch (1 = scalar).

        With a ``batch_budget`` the cap adapts per op: the largest batch
        whose single-launch latency (observed per-instance runtime x B)
        still fits the budget — ``cost_model.optimal_micro_batch`` —
        so fast ops batch deep and slow ops stay responsive, instead of
        one config constant serving both.
        """
        try:
            var = self.registry.get(oi.op.variant_name)
        except KeyError:
            return 1
        cap = var.max_batch if var.batchable else 1
        if cap <= 1 or self.batch_budget is None:
            return cap
        per_item = var.expected_runtime(self._accel_kind())
        if per_item is None:
            return cap  # nothing observed yet: static cap until then
        return max(
            1,
            optimal_micro_batch(
                op_cost_from_seconds(per_item),
                TPU_V5E,
                launch_overhead=0.0,
                latency_budget=self.batch_budget,
                max_batch=cap,
            ),
        )

    def _run_batch(self, lane: _LaneState, ois: list[OperationInstance]) -> None:
        """Execute one dispatch decision: a single op or a micro-batch
        of same-op instances (one batched call, amortized launch)."""
        var = self.registry.get(ois[0].op.variant_name)
        ts_wall = time.time()
        t0 = time.perf_counter()
        ctxs = [
            OpContext(
                chunk=oi.chunk,
                inputs=self._gather_inputs(lane, oi),
                lane_kind=lane.spec.kind,
            )
            for oi in ois
        ]
        batch_fn = (
            var.batch_implementation(lane.spec.kind) if len(ois) > 1 else None
        )
        failures: list[tuple[OperationInstance, BaseException]] = []
        if batch_fn is not None:
            for oi in ois:
                self._hook_op_start(oi)
            outs = batch_fn(ctxs)
            if len(outs) != len(ctxs):
                raise RuntimeError(
                    f"batch implementation of {var.name!r} returned "
                    f"{len(outs)} outputs for {len(ctxs)} contexts"
                )
            pairs = list(zip(ois, outs))
        else:
            # Scalar loop: isolate failures to the failing chunk so one
            # malformed tile cannot poison its batch-mates' results.
            impl = var.implementation(lane.spec.kind)
            pairs = []
            for oi, ctx in zip(ois, ctxs):
                try:
                    self._hook_op_start(oi)
                    pairs.append((oi, impl(ctx)))
                except BaseException as exc:  # noqa: BLE001 - recorded
                    failures.append((oi, exc))
        elapsed = time.perf_counter() - t0
        lane.busy_seconds += elapsed
        lane.executed += len(ois)
        self.op_runtime_hist.observe(elapsed / len(ois))
        with self._lock:
            per_op = elapsed / len(ois)
            for oi in ois:
                suid = oi.stage_instance.uid
                self._stage_exec[suid] = (
                    self._stage_exec.get(suid, 0.0) + per_op
                )
        if self.tracer is not None:
            # One span per op instance (batch-mates share ts/dur): each
            # chains under its own stage's context so a request timeline
            # shows exactly which lane ran which op, and when.  The ctx
            # tag was written by submit_stage under the worker lock
            # before the op could queue, so the lock-free read here is
            # safe; unsampled ops carry no tag and cost one getattr.
            tid = None
            for oi in ois:
                sctx = getattr(oi, "_trace_ctx", None)
                if sctx is None:
                    continue
                if tid is None:
                    tid = f"{lane.spec.kind}{lane.spec.index}"
                sub = self.tracer.child(sctx)
                self.tracer.record_span(
                    f"op:{oi.op.name}",
                    ctx=sub,
                    parent=sctx.span_id,
                    cat="op",
                    ts=ts_wall,
                    dur=elapsed,
                    tid=tid,
                    args={"uid": oi.uid, "batch": len(ois)},
                )
        if self.observe_runtimes:
            var.observe_runtime(lane.spec.kind, elapsed / len(ois))
            if self.scheduler.policy == "pats":
                # Keep the ready queue consistent with the shifted EMA —
                # but only pay the O(queue) re-sort when the estimate
                # materially moved (PATS only needs relative order).
                est = var.estimate_speedup(
                    self._accel_kind(), ois[0].chunk.meta
                )
                last = self._reorder_est.get(var.name)
                if last is None or abs(est - last) > 0.1 * max(last, 1e-9):
                    self._reorder_est[var.name] = est
                    with self._lock:
                        self.scheduler.reestimate(self._estimate_of)
        for oi, out in pairs:
            self._commit(lane, oi, out)
        self._record_failures(failures)

    def _hook_op_start(self, oi: OperationInstance) -> None:
        hook = self.on_op_start
        if hook is not None:
            hook(self, oi)

    def _record_failures(
        self, failures: list[tuple[OperationInstance, BaseException]]
    ) -> None:
        """Record op failures and report each newly-failed stage upstream
        exactly once.  The stage's remaining ops are cancelled — a failed
        stage can never complete, so leaving them queued only wastes
        lanes — and ``on_stage_failed`` fires with the worker lock
        released (lock order is manager -> worker).  A killed worker does
        not report: death attribution is the Manager's job."""
        if not failures:
            return
        report: list[tuple[StageInstance, BaseException]] = []
        with self._lock:
            for oi, exc in failures:
                self.errors.append((oi.uid, exc))
                si = oi.stage_instance
                if si.uid in self._failed_stages:
                    continue
                self._failed_stages.add(si.uid)
                for o in si.op_instances:
                    if o.uid not in self._op_done:
                        self._cancelled.add(o.uid)
                report.append((si, exc))
            self._work_ready.notify_all()
        if not self.alive or self.on_stage_failed is None:
            return
        for si, exc in report:
            try:
                self.on_stage_failed(si, f"{type(exc).__name__}: {exc}")
            except Exception:  # noqa: BLE001 - reporting is best-effort
                pass

    def _gather_inputs(self, lane: _LaneState, oi: OperationInstance) -> dict[str, Any]:
        """Upload phase: pull dep outputs into this lane's memory.

        Deps already resident in *this* lane's DeviceMemory take the
        chained fast path: no host-tier read, no re-upload.  Deps held
        device-only by a sibling lane are downloaded (materialized to
        the host tier) first — the classic cross-device route.
        """
        fetch_uids: list[int] = []
        with self._lock:
            dep_objs: list[tuple[int, Any]] = []
            for uid in sorted(oi.deps):
                if lane.memory is not None and uid in lane.memory:
                    # Device-resident fast path: skip host materialization.
                    # (Counter gated on chaining: plain-DL residency
                    # reuse must not contaminate the chaining stats.)
                    if self.chaining:
                        self.chain_hits += 1
                    dep_objs.append((uid, lane.memory.get(uid)))
                    continue
                if self.chaining and uid in self._host_chained:
                    # Host-resident chained fast path: the producer ran
                    # on a host lane and deferred the region-store write;
                    # serve the value by reference, no tier churn.
                    self.host_chain_hits += 1
                    dep_objs.append((uid, self._host_chained[uid]))
                    continue
                # Host-side read through the region store (promotes from
                # a slow tier if the StagingAgent has not gotten there
                # yet), falling back to a sibling lane's device memory.
                value = self.store.get(op_key(uid), promote=True)
                if value is None:
                    value = self._materialize_locked(uid)
                if value is None:
                    fetch_uids.append(uid)
                dep_objs.append((uid, value))
        # An input marked available but since evicted (soft tier budgets)
        # is re-pulled from the Manager synchronously.  Deliberately
        # outside self._lock: the fetch takes the Manager's lock, and the
        # Manager calls into this worker while holding it (lock order is
        # always manager -> worker).
        if fetch_uids:
            sctx = None
            if self.tracer is not None:
                with self._lock:
                    sctx = self._stage_ctx.get(oi.stage_instance.uid)
            ts_wall = time.time()
            t_fetch = time.perf_counter()
            fetched = {uid: self._fetch_region(op_key(uid)) for uid in fetch_uids}
            self.pull_latency_hist.observe(time.perf_counter() - t_fetch)
            if sctx is not None:
                sub = self.tracer.child(sctx)
                self.tracer.record_span(
                    "region:pull",
                    ctx=sub,
                    parent=sctx.span_id,
                    cat="region",
                    ts=ts_wall,
                    dur=time.perf_counter() - t_fetch,
                    tid=f"{lane.spec.kind}{lane.spec.index}",
                    args={"keys": len(fetch_uids)},
                )
            dep_objs = [
                (uid, v if v is not None else fetched.get(uid))
                for uid, v in dep_objs
            ]
            with self._lock:
                # Resolved synchronously: retire any async-pull
                # attribution so the agent's later landing (if any)
                # does not double-count the transfer.
                for uid in fetch_uids:
                    self._pull_ctx.pop(op_key(uid), None)
                    self._pull_t0.pop(op_key(uid), None)
        inputs: dict[str, Any] = {}
        with self._lock:
            for uid, value in dep_objs:
                if value is None:
                    continue
                name = self._dep_name(oi, uid)
                if lane.memory is not None:
                    if uid not in lane.memory:
                        lane.memory.uploads += 1
                        self._device_put_locked(lane, uid, value)
                    inputs[name] = lane.memory.get(uid)
                else:
                    inputs[name] = value
        return inputs

    def _device_put_locked(self, lane: _LaneState, uid: int, value: Any) -> None:
        """Insert into a lane's device memory, writing any evicted
        device-only outputs back to the host tier (slot budgets are a
        soft cap, never a correctness hazard)."""
        for e_uid, e_val in lane.memory.put(uid, value):
            if self._device_only.pop(e_uid, None) is not None:
                lane.memory.downloads += 1
                self.chain_writebacks += 1
                self.store.put(op_key(e_uid), e_val)
                # Same invariant as _commit/_materialize: keep the only
                # host copy resident until its consumers ran.
                self.store.pin(op_key(e_uid))

    def _materialize_locked(self, uid: int) -> Any:
        """Move a chained output (device-only or host-chained) into the
        host tier so host-side consumers and remote pulls can read it."""
        if uid in self._host_chained:
            value = self._host_chained.pop(uid)
            self.host_chain_writebacks += 1
            self.store.put(op_key(uid), value)
            self.store.pin(op_key(uid))
            return value
        holder = self._device_only.get(uid)
        if holder is None or holder.memory is None or uid not in holder.memory:
            return None
        value = holder.memory.get(uid)
        del self._device_only[uid]
        holder.memory.downloads += 1
        self.chain_writebacks += 1
        self.store.put(op_key(uid), value)
        self.store.pin(op_key(uid))
        return value

    def _dep_name(self, oi: OperationInstance, dep_uid: int) -> str:
        # Wiring-time name map: correct even when this worker never saw
        # the producing stage (data-plane pull / predictive push).
        name = oi.dep_names.get(dep_uid)
        if name is not None:
            return name
        si = oi.stage_instance
        for other in si.op_instances:
            if other.uid == dep_uid:
                return other.op.name
        # Cross-stage dep: find in any known stage.
        for s in self._stages.values():
            for other in s.op_instances:
                if other.uid == dep_uid:
                    return other.op.name
        return f"dep_{dep_uid}"

    def _chainable_locked(self, oi: OperationInstance) -> bool:
        """Defer the host write-back?  Only when every consumer of this
        output is known locally — a chained intermediate is then served
        straight from device memory (or lazily downloaded on a sibling
        lane / stage-completion read)."""
        if not self.chaining or not oi.dependents:
            return False
        for dep_uid in oi.dependents:
            if dep_uid in self._cancelled:
                return False
            if self._find_op(dep_uid) is None:
                return False
        return True

    def _commit(self, lane: _LaneState, oi: OperationInstance, out: Any) -> None:
        with self._lock:
            chained = False
            host_chained = False
            if lane.memory is not None:
                self._device_put_locked(lane, oi.uid, out)
                chained = self._chainable_locked(oi)
                if not chained and not self.locality:
                    lane.memory.downloads += 1  # basic mode: always download
            elif self.chaining and self._chainable_locked(oi):
                # Chained CPU lane: every consumer is known locally, so
                # the intermediate skips the region-store round-trip
                # (lock + tier accounting + pin churn) entirely.
                host_chained = True
            if chained:
                # Resident fast path: the intermediate never touches the
                # host tier unless a host-side consumer materializes it.
                self._device_only[oi.uid] = lane
                self.chain_deferred += 1
            elif host_chained:
                self._host_chained[oi.uid] = out
                self.host_chain_deferred += 1
            else:
                self.store.put(op_key(oi.uid), out)  # host write-back (download)
                # Keep the output resident until its consumers (and the
                # stage-completion read below) ran: tier budgets are a
                # soft cap for the live working set, never a correctness
                # hazard.
                self.store.pin(op_key(oi.uid))
            self._op_done.add(oi.uid)
            self.completion_order.append(oi.uid)
            si = oi.stage_instance
            for dep_uid in sorted(oi.dependents):
                d = self._find_op(dep_uid)
                if (
                    d is not None
                    and d.deps.issubset(self._op_done)
                    and dep_uid not in self._op_done
                    and dep_uid not in self._cancelled
                ):
                    self._maybe_estimate(d)
                    self.scheduler.push(d)
            # A producer whose local consumers all finished may be
            # evicted again (cross-worker consumers are re-fed by the
            # Manager from its own output copy if needed).
            for dep_uid in oi.deps:
                self._maybe_unpin_locked(dep_uid)
            stage_done = all(
                o.uid in self._op_done or o.uid in self._cancelled
                for o in si.op_instances
            )
            sctx = self._stage_ctx.pop(si.uid, None) if stage_done else None
            exec_s = self._stage_exec.pop(si.uid, None) if stage_done else None
            self._work_ready.notify_all()
        # Callbacks into the Manager happen with the worker lock
        # released: lock order is always manager -> worker, never the
        # reverse (the Manager calls submit/provide/mark under its own
        # lock, so calling it while holding ours would deadlock).
        if self.on_heartbeat is not None:
            self.on_heartbeat(self.worker_id)
        if stage_done and self.on_stage_complete is not None:
            with self._lock:
                # Only sink outputs cross the host boundary (the
                # Manager forwards them to dependents / other workers):
                # those are downloaded for real.  Chained intermediates
                # never touch the host tier — the callback still
                # carries the in-process reference (this runtime holds
                # device values in host RAM anyway), but no download is
                # modeled and tracking ends so the device LRU can age
                # them out without a write-back.
                sinks = set(si.stage.sinks())
                outputs: dict[str, Any] = {}
                for o in si.op_instances:
                    holder = self._device_only.get(o.uid)
                    if holder is None and o.uid in self._host_chained:
                        if o.op.name in sinks:
                            # Sinks cross the worker boundary: land them
                            # in the host tier for directory pulls.
                            outputs[o.op.name] = self._materialize_locked(o.uid)
                        else:
                            # Intermediate: consumers all ran; hand the
                            # reference over and end tracking.
                            outputs[o.op.name] = self._host_chained.pop(o.uid)
                    elif holder is None:
                        outputs[o.op.name] = self.store.get(op_key(o.uid))
                    elif o.op.name in sinks:
                        outputs[o.op.name] = self._materialize_locked(o.uid)
                    else:
                        del self._device_only[o.uid]
                        mem = holder.memory
                        outputs[o.op.name] = (
                            mem.get(o.uid)
                            if mem is not None and o.uid in mem
                            else None
                        )
                for o in si.op_instances:
                    self._maybe_unpin_locked(o.uid)
            # Re-install the stage's trace context around the completion
            # callback: the stage_complete RPC (and any pushes the
            # Manager derives from it) then carries the request's trace.
            with use_context(sctx):
                self.on_stage_complete(si, outputs, exec_s)

    def _maybe_unpin_locked(self, uid: int) -> None:
        """Unpin ``uid``'s output once no locally-known op still needs it."""
        oi = self._find_op(uid)
        if oi is None:
            return
        if all(
            u in self._op_done or u in self._cancelled or self._find_op(u) is None
            for u in oi.dependents
        ):
            self.store.unpin(op_key(uid))

    def _find_op(self, uid: int) -> Optional[OperationInstance]:
        for s in self._stages.values():
            for oi in s.op_instances:
                if oi.uid == uid:
                    return oi
        return None
