"""Threaded Worker runtime — the WRM of paper Fig 5, executing for real.

A Worker is a multi-thread process.  One lane thread per compute device
(CPU core / accelerator); every lane pulls ``(data chunk, operation)``
tuples from the shared :class:`~repro.core.scheduling.ReadyScheduler`
under the configured policy and executes the operation's *function
variant* for its device kind.

Accelerator lanes model the discrete-memory hierarchy of the paper:
inputs are *uploaded* into a per-lane :class:`DeviceMemory` (LRU),
outputs are *downloaded* back to host memory unless the data-locality
scheduler keeps them resident for a dependent operation, and with
``prefetch=True`` the upload of the next selected tuple overlaps the
ongoing computation via a per-lane copy thread (§IV-D's
upload/process/download pipeline).

On a single-process deployment (this container) lanes are plain
threads; on a hybrid cluster the same class drives host cores plus one
control thread per accelerator — the WCC/Manager protocol is identical
(``core/manager.py``).
"""

from __future__ import annotations

import queue
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .scheduling import HOST_KIND, ReadyScheduler
from .variants import VariantRegistry, registry as global_registry
from .workflow import OperationInstance, StageInstance

__all__ = ["DeviceMemory", "LaneSpec", "OpContext", "WorkerRuntime"]


class DeviceMemory:
    """LRU store emulating an accelerator's discrete memory."""

    def __init__(self, slots: int = 64):
        self.slots = slots
        self._store: "OrderedDict[int, Any]" = OrderedDict()
        self.uploads = 0
        self.downloads = 0

    def put(self, uid: int, value: Any) -> None:
        self._store[uid] = value
        self._store.move_to_end(uid)
        while len(self._store) > self.slots:
            self._store.popitem(last=False)

    def get(self, uid: int) -> Any:
        value = self._store[uid]
        self._store.move_to_end(uid)
        return value

    def __contains__(self, uid: int) -> bool:
        return uid in self._store

    def resident_uids(self) -> set[int]:
        return set(self._store)


@dataclass(frozen=True)
class LaneSpec:
    kind: str = HOST_KIND
    index: int = 0
    memory_slots: int = 64


@dataclass
class OpContext:
    """What an operation implementation receives."""

    chunk: Any                       # DataChunk (payload = tile, request, ...)
    inputs: dict[str, Any]           # dep op name -> output value
    lane_kind: str = HOST_KIND

    def sole_input(self) -> Any:
        if len(self.inputs) == 1:
            return next(iter(self.inputs.values()))
        if not self.inputs:
            return self.chunk.payload
        raise ValueError(f"expected one input, have {sorted(self.inputs)}")


@dataclass
class _LaneState:
    spec: LaneSpec
    thread: Optional[threading.Thread] = None
    memory: Optional[DeviceMemory] = None
    busy_seconds: float = 0.0
    executed: int = 0
    # Prefetch double-buffer: next tuple whose inputs are being uploaded.
    staged: "queue.Queue[tuple[OperationInstance, threading.Event]]" = field(
        default_factory=lambda: queue.Queue(maxsize=1)
    )


class WorkerRuntime:
    """Executes stage instances over heterogeneous lanes."""

    def __init__(
        self,
        worker_id: int = 0,
        lanes: tuple[LaneSpec, ...] = (LaneSpec(HOST_KIND, 0),),
        *,
        policy: str = "fcfs",
        locality: bool = False,
        prefetch: bool = False,
        speedups_known: bool = True,
        variant_registry: VariantRegistry | None = None,
        on_stage_complete: Callable[[StageInstance, dict[str, Any]], None] | None = None,
        observe_runtimes: bool = True,
        on_heartbeat=None,
    ) -> None:
        self.worker_id = worker_id
        self.on_heartbeat = on_heartbeat
        self.registry = variant_registry or global_registry
        self.scheduler = ReadyScheduler(
            policy=policy, locality=locality, speedups_known=speedups_known
        )
        self.prefetch = prefetch
        self.locality = locality
        self.observe_runtimes = observe_runtimes
        self.on_stage_complete = on_stage_complete

        self._lanes = [
            _LaneState(
                spec=s,
                memory=DeviceMemory(s.memory_slots) if s.kind != HOST_KIND else None,
            )
            for s in lanes
        ]
        self._lock = threading.RLock()
        self._work_ready = threading.Condition(self._lock)
        self._stop = False
        self._failed = False

        # Execution state.
        self._op_outputs: dict[int, Any] = {}      # uid -> host-resident output
        self._op_done: set[int] = set()
        self._cancelled: set[int] = set()
        self._stages: dict[int, StageInstance] = {}
        self.completion_order: list[int] = []
        self.errors: list[tuple[int, BaseException]] = []

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        for lane in self._lanes:
            t = threading.Thread(
                target=self._lane_loop, args=(lane,), daemon=True,
                name=f"worker{self.worker_id}-{lane.spec.kind}{lane.spec.index}",
            )
            lane.thread = t
            t.start()

    def stop(self) -> None:
        with self._lock:
            self._stop = True
            self._work_ready.notify_all()
        for lane in self._lanes:
            if lane.thread is not None:
                lane.thread.join(timeout=5.0)

    def kill(self) -> None:
        """Simulate a node failure: lanes stop, state is lost."""
        with self._lock:
            self._failed = True
            self._stop = True
            self._work_ready.notify_all()

    @property
    def alive(self) -> bool:
        return not self._failed

    # -- submission -----------------------------------------------------------

    def submit_stage(self, si: StageInstance) -> None:
        """Lease received from the Manager: export fine-grain ops."""
        with self._lock:
            self._stages[si.uid] = si
            for oi in si.op_instances:
                self._maybe_estimate(oi)
                if oi.deps.issubset(self._op_done) and oi.uid not in self._op_done:
                    self.scheduler.push(oi)
            self._work_ready.notify_all()

    def provide_input(self, uid: int, value: Any) -> None:
        """Host-side injection of upstream outputs (cross-worker flow)."""
        with self._lock:
            self._op_outputs[uid] = value
            self._op_done.add(uid)

    def cancel_stage(self, si_uid: int) -> None:
        with self._lock:
            si = self._stages.get(si_uid)
            if si is None:
                return
            for oi in si.op_instances:
                if oi.uid not in self._op_done:
                    self._cancelled.add(oi.uid)

    def _maybe_estimate(self, oi: OperationInstance) -> None:
        try:
            var = self.registry.get(oi.op.variant_name)
        except KeyError:
            return
        accel_kinds = {l.spec.kind for l in self._lanes} - {HOST_KIND}
        kind = next(iter(accel_kinds)) if accel_kinds else HOST_KIND
        oi.speedup = var.estimate_speedup(kind, oi.chunk.meta)
        oi.transfer_impact = var.transfer_impact

    # -- idle / completion tracking -----------------------------------------

    def drain(self, timeout: float = 60.0) -> bool:
        """Block until all submitted work completed (or timeout)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                pending = any(
                    oi.uid not in self._op_done and oi.uid not in self._cancelled
                    for si in self._stages.values()
                    for oi in si.op_instances
                )
                if self.errors:
                    return False
                if not pending:
                    return True
            time.sleep(0.002)
        return False

    def stats(self) -> dict[str, Any]:
        return {
            "profile": self.scheduler.stats.profile(),
            "reuse_hits": self.scheduler.stats.reuse_hits,
            "reuse_misses": self.scheduler.stats.reuse_misses,
            "lane_busy": {
                f"{l.spec.kind}{l.spec.index}": l.busy_seconds for l in self._lanes
            },
            "executed": sum(l.executed for l in self._lanes),
            "uploads": sum(
                l.memory.uploads for l in self._lanes if l.memory is not None
            ),
            "downloads": sum(
                l.memory.downloads for l in self._lanes if l.memory is not None
            ),
        }

    def output_of(self, oi_uid: int) -> Any:
        with self._lock:
            return self._op_outputs.get(oi_uid)

    # -- lane main loop -----------------------------------------------------------

    def _lane_loop(self, lane: _LaneState) -> None:
        while True:
            with self._lock:
                while not self._stop and not self.scheduler:
                    self._work_ready.wait(timeout=0.25)
                if self._stop:
                    return
                resident = (
                    lane.memory.resident_uids()
                    if lane.memory is not None and self.locality
                    else None
                )
                oi = self.scheduler.pop(lane.spec.kind, resident)
            if oi is None:
                continue
            if oi.uid in self._cancelled or oi.uid in self._op_done:
                continue
            try:
                self._run_op(lane, oi)
            except BaseException as exc:  # noqa: BLE001 - recorded, not raised
                with self._lock:
                    self.errors.append((oi.uid, exc))
                    self._work_ready.notify_all()

    def _run_op(self, lane: _LaneState, oi: OperationInstance) -> None:
        t0 = time.perf_counter()
        inputs = self._gather_inputs(lane, oi)
        ctx = OpContext(chunk=oi.chunk, inputs=inputs, lane_kind=lane.spec.kind)
        impl = self.registry.get(oi.op.variant_name).implementation(lane.spec.kind)
        out = impl(ctx)
        elapsed = time.perf_counter() - t0
        lane.busy_seconds += elapsed
        lane.executed += 1
        if self.observe_runtimes:
            self.registry.get(oi.op.variant_name).observe_runtime(
                lane.spec.kind, elapsed
            )
        self._commit(lane, oi, out)

    def _gather_inputs(self, lane: _LaneState, oi: OperationInstance) -> dict[str, Any]:
        """Upload phase: pull dep outputs into this lane's memory."""
        inputs: dict[str, Any] = {}
        with self._lock:
            dep_objs = [
                (uid, self._op_outputs.get(uid)) for uid in sorted(oi.deps)
            ]
        for uid, value in dep_objs:
            if value is None:
                continue
            name = self._dep_name(oi, uid)
            if lane.memory is not None:
                if uid not in lane.memory:
                    lane.memory.uploads += 1
                    lane.memory.put(uid, value)
                inputs[name] = lane.memory.get(uid)
            else:
                inputs[name] = value
        return inputs

    def _dep_name(self, oi: OperationInstance, dep_uid: int) -> str:
        si = oi.stage_instance
        for other in si.op_instances:
            if other.uid == dep_uid:
                return other.op.name
        # Cross-stage dep: find in any known stage.
        for s in self._stages.values():
            for other in s.op_instances:
                if other.uid == dep_uid:
                    return other.op.name
        return f"dep_{dep_uid}"

    def _commit(self, lane: _LaneState, oi: OperationInstance, out: Any) -> None:
        with self._lock:
            if lane.memory is not None:
                lane.memory.put(oi.uid, out)
                if not self.locality:
                    lane.memory.downloads += 1  # basic mode: always download
            self._op_outputs[oi.uid] = out  # host copy (download / write-back)
            self._op_done.add(oi.uid)
            self.completion_order.append(oi.uid)
            if self.on_heartbeat is not None:
                self.on_heartbeat(self.worker_id)
            si = oi.stage_instance
            for dep_uid in sorted(oi.dependents):
                d = self._find_op(dep_uid)
                if (
                    d is not None
                    and d.deps.issubset(self._op_done)
                    and dep_uid not in self._op_done
                    and dep_uid not in self._cancelled
                ):
                    self._maybe_estimate(d)
                    self.scheduler.push(d)
            stage_done = all(
                o.uid in self._op_done or o.uid in self._cancelled
                for o in si.op_instances
            )
            self._work_ready.notify_all()
        if stage_done and self.on_stage_complete is not None:
            outputs = {
                o.op.name: self._op_outputs.get(o.uid) for o in si.op_instances
            }
            self.on_stage_complete(si, outputs)

    def _find_op(self, uid: int) -> Optional[OperationInstance]:
        for s in self._stages.values():
            for oi in s.op_instances:
                if oi.uid == uid:
                    return oi
        return None
