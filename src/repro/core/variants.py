"""Function-variant registry (paper §III-A).

A *function variant* is a group of implementations with the same name,
arguments and result types, one per device kind.  Binding a logical
operation to a variant lets the runtime pick the implementation that
matches whatever compute lane the scheduler chose — CPU core, GPU,
TPU-interpret, ... — so heterogeneous devices are used concurrently and
in coordination.

The registry also carries per-variant *speedup estimates* (accelerator
vs one host core) which feed the PATS scheduler.  Estimates may be

* static (registered alongside the implementation),
* data-dependent (a callable of the chunk's ``meta``), or
* learned online from observed runtimes (exponential moving average),

mirroring the paper's observation that both per-operation and per-chunk
variability exist.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

__all__ = ["FunctionVariant", "VariantRegistry", "registry"]

SpeedupFn = Callable[[Mapping[str, Any]], float]


@dataclass
class FunctionVariant:
    """All registered implementations of one logical operation."""

    name: str
    impls: dict[str, Callable[..., Any]] = field(default_factory=dict)
    # accelerator-vs-host speedup estimate; key is accelerator kind
    speedup: dict[str, float] = field(default_factory=dict)
    speedup_fn: dict[str, SpeedupFn] = field(default_factory=dict)
    # fraction of exec time spent on host<->device transfers
    transfer_impact: float = 0.0
    # Micro-batched dispatch: a batchable variant allows an idle
    # accelerator lane to pop up to ``max_batch`` ready instances of
    # this op and execute them as one (v)mapped kernel call.  Only ops
    # whose implementation compiles once per chunk shape (regular,
    # shape-stable) should declare this.
    batchable: bool = False
    max_batch: int = 1
    # kind -> batched implementation taking a list of OpContexts and
    # returning a same-length list of outputs.  Absent => the runtime
    # loops the scalar implementation (still one dispatch decision).
    batch_impls: dict[str, Callable[..., Any]] = field(default_factory=dict)
    # online estimator state: kind -> (ema_runtime, n_obs)
    _observed: dict[str, tuple[float, int]] = field(default_factory=dict)

    def implementation(self, device_kind: str) -> Callable[..., Any]:
        if device_kind in self.impls:
            return self.impls[device_kind]
        # Fall back to the host implementation: a variant is allowed to
        # exist only for some kinds (e.g. no accelerator port yet).
        if "cpu" in self.impls:
            return self.impls["cpu"]
        raise KeyError(
            f"variant {self.name!r} has no implementation for {device_kind!r}"
        )

    def supports(self, device_kind: str) -> bool:
        return device_kind in self.impls

    def batch_implementation(
        self, device_kind: str
    ) -> Callable[..., Any] | None:
        """Batched implementation for ``device_kind`` (None => loop)."""
        return self.batch_impls.get(device_kind)

    def estimate_speedup(
        self, device_kind: str, meta: Mapping[str, Any] | None = None
    ) -> float:
        """Estimated speedup of running on ``device_kind`` vs one host core."""
        if device_kind == "cpu":
            return 1.0
        # Online observations dominate once both kinds have been timed.
        obs = self._observed
        if "cpu" in obs and device_kind in obs and obs[device_kind][1] >= 2:
            return max(obs["cpu"][0] / max(obs[device_kind][0], 1e-12), 1e-6)
        if device_kind in self.speedup_fn and meta is not None:
            return self.speedup_fn[device_kind](meta)
        return self.speedup.get(device_kind, 1.0)

    def observe_runtime(self, device_kind: str, seconds: float) -> None:
        ema, n = self._observed.get(device_kind, (seconds, 0))
        alpha = 0.3
        self._observed[device_kind] = (alpha * seconds + (1 - alpha) * ema, n + 1)

    def expected_runtime(self, device_kind: str) -> float | None:
        """Online EMA of the per-instance runtime on ``device_kind``
        (None until observed) — feeds the adaptive micro-batch sizing
        (``cost_model.optimal_micro_batch`` latency-budget curve)."""
        obs = self._observed.get(device_kind)
        return obs[0] if obs is not None else None


class VariantRegistry:
    """Thread-safe name -> FunctionVariant map."""

    def __init__(self) -> None:
        self._variants: dict[str, FunctionVariant] = {}
        self._lock = threading.Lock()

    def register(
        self,
        name: str,
        device_kind: str,
        fn: Callable[..., Any],
        *,
        speedup: float | None = None,
        speedup_fn: SpeedupFn | None = None,
        transfer_impact: float | None = None,
        batchable: bool | None = None,
        max_batch: int | None = None,
        batch_fn: Callable[..., Any] | None = None,
    ) -> FunctionVariant:
        with self._lock:
            var = self._variants.setdefault(name, FunctionVariant(name))
            var.impls[device_kind] = fn
            if speedup is not None:
                var.speedup[device_kind] = speedup
            if speedup_fn is not None:
                var.speedup_fn[device_kind] = speedup_fn
            if transfer_impact is not None:
                var.transfer_impact = transfer_impact
            if batchable is not None:
                var.batchable = batchable
            if batch_fn is not None:
                var.batch_impls[device_kind] = batch_fn
                var.batchable = True
            if max_batch is not None:
                var.max_batch = max_batch
            elif var.batchable and var.max_batch <= 1:
                var.max_batch = 8  # usable default once declared batchable
            return var

    def cpu(self, name: str, **kw: Any) -> Callable[[Callable], Callable]:
        """Decorator: ``@registry.cpu("watershed")``."""
        return self._decorator(name, "cpu", **kw)

    def accel(self, name: str, kind: str = "gpu", **kw: Any):
        return self._decorator(name, kind, **kw)

    def _decorator(self, name: str, kind: str, **kw: Any):
        def deco(fn: Callable) -> Callable:
            self.register(name, kind, fn, **kw)
            return fn

        return deco

    def get(self, name: str) -> FunctionVariant:
        with self._lock:
            if name not in self._variants:
                raise KeyError(f"no function variant registered as {name!r}")
            return self._variants[name]

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._variants

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._variants)

    def clear(self) -> None:  # test hook
        with self._lock:
            self._variants.clear()


#: Process-global registry; applications may also instantiate their own.
registry = VariantRegistry()
