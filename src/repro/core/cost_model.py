"""Roofline op cost model — PATS speedup estimates from first principles.

The paper obtains per-operation GPU-vs-CPU speedup estimates by
profiling.  At TPU-pod scale profiling every (op, shape) is impractical,
so this framework *derives* the estimate from a roofline model: an op is
characterized by FLOPs, bytes moved, and (optionally) collective bytes;
a device lane is characterized by peak FLOP/s, memory bandwidth and
link bandwidth.  The predicted runtime is

    t(lane) = max(flops / peak, bytes / mem_bw) + coll_bytes / link_bw

and the PATS estimate for an accelerator lane is
``t(host_core) / t(accel)``.  PATS only needs the *relative order* of
these estimates to be right (paper §V-G shows tolerance to ~60% error),
which a roofline model comfortably delivers.

The same constants feed the §Roofline analysis of the dry-run
(see ``launch/dryrun.py``).
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = [
    "LaneModel",
    "OpCost",
    "TPU_V5E",
    "HOST_CORE",
    "predicted_runtime",
    "estimate_speedup",
    "roofline_terms",
    "batched_runtime",
    "batch_amortization",
    "optimal_micro_batch",
    "op_cost_from_seconds",
]


@dataclass(frozen=True)
class LaneModel:
    """Throughput model of one compute lane."""

    name: str
    peak_flops: float        # FLOP/s (dense matmul peak for MXU lanes)
    mem_bw: float            # bytes/s to the lane's fast memory
    link_bw: float = 5e10    # bytes/s per ICI link (collectives)
    vector_flops: float | None = None  # non-MXU (VPU) peak, if different

    def effective_flops(self, mxu_friendly: bool) -> float:
        if mxu_friendly or self.vector_flops is None:
            return self.peak_flops
        return self.vector_flops


#: TPU v5e chip (per spec sheet): 197 TFLOP/s bf16, 819 GB/s HBM,
#: ~50 GB/s/link ICI.  VPU (vector) peak is ~2 orders below the MXU.
TPU_V5E = LaneModel(
    name="tpu_v5e",
    peak_flops=197e12,
    mem_bw=819e9,
    link_bw=50e9,
    vector_flops=4e12,
)

#: One modern host CPU core: ~100 GFLOP/s, ~20 GB/s effective DRAM bw.
HOST_CORE = LaneModel(
    name="host_core", peak_flops=1e11, mem_bw=2e10, link_bw=1e10
)


@dataclass(frozen=True)
class OpCost:
    """Workload characterization of one operation on one data chunk."""

    flops: float
    bytes: float
    coll_bytes: float = 0.0
    mxu_friendly: bool = True  # dense matmul-like (vs gather/scan-like)

    @property
    def intensity(self) -> float:
        return self.flops / max(self.bytes, 1.0)


def predicted_runtime(cost: OpCost, lane: LaneModel) -> float:
    compute = cost.flops / lane.effective_flops(cost.mxu_friendly)
    memory = cost.bytes / lane.mem_bw
    collective = cost.coll_bytes / lane.link_bw
    return max(compute, memory) + collective


def estimate_speedup(
    cost: OpCost, accel: LaneModel = TPU_V5E, host: LaneModel = HOST_CORE
) -> float:
    """PATS estimate: host-core runtime / accelerator runtime."""
    return predicted_runtime(cost, host) / max(
        predicted_runtime(cost, accel), 1e-15
    )


def batched_runtime(
    cost: OpCost,
    lane: LaneModel,
    batch: int,
    launch_overhead: float,
) -> float:
    """Runtime of one batched launch over ``batch`` identical chunks.

    The streaming terms (compute, memory, collectives) scale linearly
    with the batch — a vmapped kernel reads ``batch`` tiles — while the
    fixed dispatch cost (driver launch, JIT cache lookup, control
    round-trip) is paid once.  This is the amortization curve the
    micro-batched dispatcher trades against latency.
    """
    return launch_overhead + batch * predicted_runtime(cost, lane)


def batch_amortization(
    cost: OpCost,
    lane: LaneModel,
    batch: int,
    launch_overhead: float,
) -> float:
    """Speedup of one batched launch vs ``batch`` sequential launches."""
    sequential = batch * (launch_overhead + predicted_runtime(cost, lane))
    return sequential / max(
        batched_runtime(cost, lane, batch, launch_overhead), 1e-15
    )


def optimal_micro_batch(
    cost: OpCost,
    lane: LaneModel,
    launch_overhead: float,
    latency_budget: float,
    max_batch: int = 64,
) -> int:
    """Largest batch whose single-launch latency fits the budget.

    Amortization is monotone in the batch size, so the best batch is
    the largest one the op's latency budget (e.g. the drain tail the
    scheduler can tolerate) still admits.
    """
    best = 1
    for b in range(2, max_batch + 1):
        if batched_runtime(cost, lane, b, launch_overhead) > latency_budget:
            break
        best = b
    return best


def op_cost_from_seconds(
    accel_seconds: float,
    lane: LaneModel = TPU_V5E,
    mxu_friendly: bool = True,
) -> OpCost:
    """Synthesize an :class:`OpCost` whose roofline runtime on ``lane``
    equals a measured / calibrated per-instance runtime.

    The dispatcher knows per-op *seconds* (calibrated profiles, online
    EMAs) rather than flop counts; this adapter lets those timings
    drive the batching curves (``batched_runtime`` /
    ``optimal_micro_batch``) without hand-characterizing every op.
    The cost is compute-bound by construction (memory term at half the
    compute term), which is the regime where batching pays anyway.
    """
    s = max(accel_seconds, 1e-12)
    return OpCost(
        flops=s * lane.effective_flops(mxu_friendly),
        bytes=s * lane.mem_bw / 2.0,
        mxu_friendly=mxu_friendly,
    )


def roofline_terms(
    flops: float,
    bytes_: float,
    coll_bytes: float,
    n_chips: int,
    lane: LaneModel = TPU_V5E,
) -> dict[str, float]:
    """The three §Roofline terms, in seconds, for an n-chip execution."""
    return {
        "compute_s": flops / (n_chips * lane.peak_flops),
        "memory_s": bytes_ / (n_chips * lane.mem_bw),
        "collective_s": coll_bytes / (n_chips * lane.link_bw),
    }
