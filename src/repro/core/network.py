"""Per-link cluster network topology model (the simulator's data plane).

The seed simulator modeled the cluster interconnect as one scalar
bandwidth per node: every copy into a node serialized on that node's
ingress NIC and nothing else.  Real clusters are switched fabrics —
a transfer occupies its **source NIC**, any **shared switch uplinks**
on the path, and the **destination NIC**, in that order, and the
uplink tier is usually *oversubscribed* (a rack of ``r`` nodes shares
an uplink of ``r * link / oversubscription`` capacity).  Whether
locality-aware placement pays off depends exactly on that contention:
on a flat (non-blocking) network every placement is one hop, while on
a 4:1 fat-tree a rack-blind placement pays the shared uplink for
every cross-rack region and a rack-aware one bypasses it.

This module is the pluggable model behind
``SimConfig.network``:

* :class:`FlatNetwork` — single tier, non-blocking: each transfer
  serializes on the source egress NIC and the destination ingress NIC
  only.  With the source unknown (``src=None``) it degrades to the
  seed's destination-NIC-only model.
* :class:`FatTreeNetwork` — two-tier leaf/spine tree: nodes are
  grouped into racks of ``rack_size``; an intra-rack transfer stays on
  the leaf switch (NICs only), a cross-rack transfer additionally
  serializes on the source rack's up-link and the destination rack's
  down-link, each of capacity ``rack_size * link_gb_s /
  oversubscription``.  ``oversubscription=1`` is a full-bisection
  (non-blocking) tree; ``4`` is the classic cost-reduced 4:1 fabric.

Both models also carry the **coordinator NIC** used by the relay
route (data plane disabled): relayed bytes cross that single shared
link twice (in + out), which is the structural bottleneck the
worker-to-worker data plane removes (see ``docs/architecture.md``,
"data plane").

Every link keeps byte and busy-time accounting so results can report
where the wire time went (``SimResult.cross_rack_bytes`` /
``uplink_busy_s``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

__all__ = [
    "Link",
    "NetworkModel",
    "FlatNetwork",
    "FatTreeNetwork",
    "build_network",
]

_GB = float(2**30)


@dataclass
class Link:
    """One serializing network resource (a NIC or a switch uplink).

    Transfers reserve the link back-to-back: a reservation starts at
    ``max(earliest, busy_until)`` and holds the link for
    ``nbytes / bandwidth`` seconds — the same store-and-forward rule
    the seed model applied to the single ingress NIC, now applied to
    every hop on the path.
    """

    name: str
    gb_s: float
    busy_until: float = 0.0
    busy_seconds: float = 0.0
    bytes_total: int = 0

    def reserve(self, earliest: float, nbytes: int) -> float:
        start = max(earliest, self.busy_until)
        dt = nbytes / (self.gb_s * _GB)
        self.busy_until = start + dt
        self.busy_seconds += dt
        self.bytes_total += int(nbytes)
        return self.busy_until


class NetworkModel:
    """Base contract + the flat (single-tier, non-blocking) fabric.

    ``transfer(src, dst, nbytes, earliest)`` returns the time the last
    byte lands on ``dst``, having serialized the transfer on every
    link of the path; ``relay`` is the coordinator route (bytes cross
    the coordinator NIC twice).  ``rack_of`` exposes topology identity
    to placement: ``None`` means this fabric has no racks.
    """

    kind = "flat"

    def __init__(
        self,
        n_nodes: int,
        link_gb_s: float,
        *,
        coordinator_gb_s: Optional[float] = None,
    ) -> None:
        self.n_nodes = int(n_nodes)
        self.link_gb_s = float(link_gb_s)
        self.ingress = [
            Link(f"nic-in{i}", link_gb_s) for i in range(self.n_nodes)
        ]
        self.egress = [
            Link(f"nic-out{i}", link_gb_s) for i in range(self.n_nodes)
        ]
        # The relay route's shared coordinator NIC (one link for the
        # whole cluster; carries every relayed byte twice).
        self.coordinator = Link(
            "coordinator-nic", coordinator_gb_s or link_gb_s
        )
        self.rack_local_bytes = 0
        self.cross_rack_bytes = 0

    # -- topology identity --------------------------------------------------

    def rack_of(self, node_id: int) -> Optional[int]:
        """Rack (leaf switch) of ``node_id``; None = no rack tier."""
        return None

    def same_rack(self, a: Optional[int], b: Optional[int]) -> bool:
        ra = self.rack_of(a) if a is not None else None
        rb = self.rack_of(b) if b is not None else None
        return ra is not None and ra == rb

    # -- path construction --------------------------------------------------

    def path(self, src: Optional[int], dst: int) -> list[Link]:
        """Links a ``src -> dst`` transfer serializes on, in order.

        ``src=None`` (holder unknown to the model) charges only the
        destination NIC — the seed behavior, kept as the conservative
        fallback.
        """
        if src is None:
            return [self.ingress[dst]]
        if src == dst:
            return []
        return [self.egress[src], self.ingress[dst]]

    # -- transfers ----------------------------------------------------------

    def transfer(
        self, src: Optional[int], dst: int, nbytes: int, earliest: float
    ) -> float:
        """Direct (worker-to-worker) transfer; returns completion time."""
        links = self.path(src, dst)
        if not links:
            return earliest
        t = earliest
        for link in links:
            t = link.reserve(t, nbytes)
        # Rack accounting only exists on fabrics WITH a rack tier: a
        # flat network has no uplinks, so calling its traffic
        # "cross-rack" would make flat-vs-fat-tree rows incomparable.
        if src is not None and self.rack_of(dst) is not None:
            if self.same_rack(src, dst):
                self.rack_local_bytes += int(nbytes)
            else:
                self.cross_rack_bytes += int(nbytes)
        return t

    def relay(
        self, src: Optional[int], dst: int, nbytes: int, earliest: float
    ) -> float:
        """Coordinator-relay transfer: the bytes leave the source NIC,
        cross the coordinator's single shared NIC twice (in + out), and
        land through the destination NIC."""
        t = earliest
        if src is not None and src != dst:
            t = self.egress[src].reserve(t, nbytes)
        t = self.coordinator.reserve(t, 2 * nbytes)
        return self.ingress[dst].reserve(t, nbytes)

    # -- accounting ---------------------------------------------------------

    def uplink_busy_s(self) -> float:
        return 0.0

    def stats(self) -> dict[str, float]:
        return {
            "rack_local_bytes": float(self.rack_local_bytes),
            "cross_rack_bytes": float(self.cross_rack_bytes),
            "uplink_busy_s": self.uplink_busy_s(),
            "coordinator_bytes": float(self.coordinator.bytes_total),
        }


class FlatNetwork(NetworkModel):
    """Single-tier non-blocking fabric (explicit alias of the base)."""


class FatTreeNetwork(NetworkModel):
    """Two-tier fat-tree: racks of ``rack_size`` nodes behind shared
    uplinks of ``rack_size * link_gb_s / oversubscription`` capacity.

    Intra-rack transfers never touch the uplink tier — that asymmetry
    is what a rack-locality placement bonus exploits.
    """

    kind = "fat_tree"

    def __init__(
        self,
        n_nodes: int,
        link_gb_s: float,
        *,
        rack_size: int = 4,
        oversubscription: float = 4.0,
        coordinator_gb_s: Optional[float] = None,
    ) -> None:
        super().__init__(
            n_nodes, link_gb_s, coordinator_gb_s=coordinator_gb_s
        )
        self.rack_size = max(int(rack_size), 1)
        self.oversubscription = max(float(oversubscription), 1e-9)
        n_racks = (self.n_nodes + self.rack_size - 1) // self.rack_size
        up_gb_s = link_gb_s * self.rack_size / self.oversubscription
        self.uplinks_up = [
            Link(f"rack{r}-up", up_gb_s) for r in range(n_racks)
        ]
        self.uplinks_down = [
            Link(f"rack{r}-down", up_gb_s) for r in range(n_racks)
        ]

    def rack_of(self, node_id: int) -> Optional[int]:
        return int(node_id) // self.rack_size

    def path(self, src: Optional[int], dst: int) -> list[Link]:
        if src is None:
            return [self.ingress[dst]]
        if src == dst:
            return []
        links = [self.egress[src]]
        if not self.same_rack(src, dst):
            links.append(self.uplinks_up[self.rack_of(src)])
            links.append(self.uplinks_down[self.rack_of(dst)])
        links.append(self.ingress[dst])
        return links

    def uplink_busy_s(self) -> float:
        return sum(
            l.busy_seconds for l in self.uplinks_up + self.uplinks_down
        )


def build_network(
    kind: str,
    n_nodes: int,
    link_gb_s: float,
    *,
    rack_size: int = 4,
    oversubscription: float = 4.0,
    coordinator_gb_s: Optional[float] = None,
) -> NetworkModel:
    """Factory behind ``SimConfig.network``.

    ``"flat"`` — non-blocking single tier (default, seed-compatible
    plus source-NIC serialization); ``"fat_tree"`` (aliases
    ``"fat-tree"``, ``"fattree"``) — two-tier oversubscribed tree.
    """
    normalized = kind.lower().replace("-", "_").replace(" ", "_")
    if normalized == "flat":
        return FlatNetwork(
            n_nodes, link_gb_s, coordinator_gb_s=coordinator_gb_s
        )
    if normalized in ("fat_tree", "fattree"):
        return FatTreeNetwork(
            n_nodes,
            link_gb_s,
            rack_size=rack_size,
            oversubscription=oversubscription,
            coordinator_gb_s=coordinator_gb_s,
        )
    raise ValueError(f"unknown network model {kind!r}")
