"""Per-link cluster network topology model (the simulator's data plane).

The seed simulator modeled the cluster interconnect as one scalar
bandwidth per node: every copy into a node serialized on that node's
ingress NIC and nothing else.  Real clusters are switched fabrics —
a transfer occupies its **source NIC**, any **shared switch uplinks**
on the path, and the **destination NIC**, in that order, and the
uplink tier is usually *oversubscribed* (a rack of ``r`` nodes shares
an uplink of ``r * link / oversubscription`` capacity).  Whether
locality-aware placement pays off depends exactly on that contention:
on a flat (non-blocking) network every placement is one hop, while on
a 4:1 fat-tree a rack-blind placement pays the shared uplink for
every cross-rack region and a rack-aware one bypasses it.

This module is the pluggable model behind
``SimConfig.network``:

* :class:`FlatNetwork` — single tier, non-blocking: each transfer
  serializes on the source egress NIC and the destination ingress NIC
  only.  With the source unknown (``src=None``) it degrades to the
  seed's destination-NIC-only model.
* :class:`FatTreeNetwork` — two-tier leaf/spine tree: nodes are
  grouped into racks of ``rack_size``; an intra-rack transfer stays on
  the leaf switch (NICs only), a cross-rack transfer additionally
  serializes on the source rack's up-link and the destination rack's
  down-link, each of capacity ``rack_size * link_gb_s /
  oversubscription``.  ``oversubscription=1`` is a full-bisection
  (non-blocking) tree; ``4`` is the classic cost-reduced 4:1 fabric.

Both models also carry the **coordinator NIC** used by the relay
route (data plane disabled): relayed bytes cross that single shared
link twice (in + out), which is the structural bottleneck the
worker-to-worker data plane removes (see ``docs/architecture.md``,
"data plane").

Every link keeps byte and busy-time accounting so results can report
where the wire time went (``SimResult.cross_rack_bytes`` /
``uplink_busy_s``).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Optional

__all__ = [
    "Link",
    "NetworkModel",
    "FlatNetwork",
    "FatTreeNetwork",
    "FluidFlow",
    "FluidNetwork",
    "build_network",
]

_GB = float(2**30)

# A flow whose remaining payload drops below this many bytes is
# complete (absorbs float drift from repeated rate * dt advances).
_EPS_BYTES = 0.5


@dataclass(eq=False)
class Link:
    """One serializing network resource (a NIC or a switch uplink).

    Transfers reserve the link back-to-back: a reservation starts at
    ``max(earliest, busy_until)`` and holds the link for
    ``nbytes / bandwidth`` seconds — the same store-and-forward rule
    the seed model applied to the single ingress NIC, now applied to
    every hop on the path.
    """

    name: str
    gb_s: float
    busy_until: float = 0.0
    busy_seconds: float = 0.0
    bytes_total: int = 0

    def reserve(self, earliest: float, nbytes: int) -> float:
        start = max(earliest, self.busy_until)
        dt = nbytes / (self.gb_s * _GB)
        self.busy_until = start + dt
        self.busy_seconds += dt
        self.bytes_total += int(nbytes)
        return self.busy_until


class NetworkModel:
    """Base contract + the flat (single-tier, non-blocking) fabric.

    ``transfer(src, dst, nbytes, earliest)`` returns the time the last
    byte lands on ``dst``, having serialized the transfer on every
    link of the path; ``relay`` is the coordinator route (bytes cross
    the coordinator NIC twice).  ``rack_of`` exposes topology identity
    to placement: ``None`` means this fabric has no racks.
    """

    kind = "flat"

    def __init__(
        self,
        n_nodes: int,
        link_gb_s: float,
        *,
        coordinator_gb_s: Optional[float] = None,
    ) -> None:
        self.n_nodes = int(n_nodes)
        self.link_gb_s = float(link_gb_s)
        self.ingress = [
            Link(f"nic-in{i}", link_gb_s) for i in range(self.n_nodes)
        ]
        self.egress = [
            Link(f"nic-out{i}", link_gb_s) for i in range(self.n_nodes)
        ]
        # The relay route's shared coordinator NIC (one link for the
        # whole cluster; carries every relayed byte twice).
        self.coordinator = Link(
            "coordinator-nic", coordinator_gb_s or link_gb_s
        )
        self.rack_local_bytes = 0
        self.cross_rack_bytes = 0

    # -- topology identity --------------------------------------------------

    def rack_of(self, node_id: int) -> Optional[int]:
        """Rack (leaf switch) of ``node_id``; None = no rack tier."""
        return None

    def same_rack(self, a: Optional[int], b: Optional[int]) -> bool:
        ra = self.rack_of(a) if a is not None else None
        rb = self.rack_of(b) if b is not None else None
        return ra is not None and ra == rb

    # -- path construction --------------------------------------------------

    def path(self, src: Optional[int], dst: int) -> list[Link]:
        """Links a ``src -> dst`` transfer serializes on, in order.

        ``src=None`` (holder unknown to the model) charges only the
        destination NIC — the seed behavior, kept as the conservative
        fallback.
        """
        if src is None:
            return [self.ingress[dst]]
        if src == dst:
            return []
        return [self.egress[src], self.ingress[dst]]

    # -- transfers ----------------------------------------------------------

    def transfer(
        self, src: Optional[int], dst: int, nbytes: int, earliest: float
    ) -> float:
        """Direct (worker-to-worker) transfer; returns completion time."""
        links = self.path(src, dst)
        if not links:
            return earliest
        t = earliest
        for link in links:
            t = link.reserve(t, nbytes)
        self.account_rack(src, dst, nbytes)
        return t

    def account_rack(
        self, src: Optional[int], dst: int, nbytes: int
    ) -> None:
        """Book ``nbytes`` as rack-local or cross-rack traffic.

        Rack accounting only exists on fabrics WITH a rack tier: a
        flat network has no uplinks, so calling its traffic
        "cross-rack" would make flat-vs-fat-tree rows incomparable.
        Shared by the store-and-forward reservation path and the
        fluid-flow engine so both engines report comparable bytes.
        """
        if src is not None and self.rack_of(dst) is not None:
            if self.same_rack(src, dst):
                self.rack_local_bytes += int(nbytes)
            else:
                self.cross_rack_bytes += int(nbytes)

    def relay(
        self, src: Optional[int], dst: int, nbytes: int, earliest: float
    ) -> float:
        """Coordinator-relay transfer: the bytes leave the source NIC,
        cross the coordinator's single shared NIC twice (in + out), and
        land through the destination NIC."""
        t = earliest
        if src is not None and src != dst:
            t = self.egress[src].reserve(t, nbytes)
        t = self.coordinator.reserve(t, 2 * nbytes)
        return self.ingress[dst].reserve(t, nbytes)

    # -- accounting ---------------------------------------------------------

    def uplink_busy_s(self) -> float:
        return 0.0

    def nic_busy_s(self) -> float:
        """Total busy time across every node NIC (ingress + egress)."""
        return sum(l.busy_seconds for l in self.ingress) + sum(
            l.busy_seconds for l in self.egress
        )

    def n_uplinks(self) -> int:
        """Uplink-tier link count (0 = no rack tier)."""
        return 0

    def stats(self) -> dict[str, float]:
        return {
            "rack_local_bytes": float(self.rack_local_bytes),
            "cross_rack_bytes": float(self.cross_rack_bytes),
            "uplink_busy_s": self.uplink_busy_s(),
            "coordinator_bytes": float(self.coordinator.bytes_total),
        }


class FlatNetwork(NetworkModel):
    """Single-tier non-blocking fabric (explicit alias of the base)."""


class FatTreeNetwork(NetworkModel):
    """Two-tier fat-tree: racks of ``rack_size`` nodes behind shared
    uplinks of ``rack_size * link_gb_s / oversubscription`` capacity.

    Intra-rack transfers never touch the uplink tier — that asymmetry
    is what a rack-locality placement bonus exploits.
    """

    kind = "fat_tree"

    def __init__(
        self,
        n_nodes: int,
        link_gb_s: float,
        *,
        rack_size: int = 4,
        oversubscription: float = 4.0,
        coordinator_gb_s: Optional[float] = None,
    ) -> None:
        super().__init__(
            n_nodes, link_gb_s, coordinator_gb_s=coordinator_gb_s
        )
        self.rack_size = max(int(rack_size), 1)
        self.oversubscription = max(float(oversubscription), 1e-9)
        n_racks = (self.n_nodes + self.rack_size - 1) // self.rack_size
        up_gb_s = link_gb_s * self.rack_size / self.oversubscription
        self.uplinks_up = [
            Link(f"rack{r}-up", up_gb_s) for r in range(n_racks)
        ]
        self.uplinks_down = [
            Link(f"rack{r}-down", up_gb_s) for r in range(n_racks)
        ]

    def rack_of(self, node_id: int) -> Optional[int]:
        return int(node_id) // self.rack_size

    def path(self, src: Optional[int], dst: int) -> list[Link]:
        if src is None:
            return [self.ingress[dst]]
        if src == dst:
            return []
        links = [self.egress[src]]
        if not self.same_rack(src, dst):
            links.append(self.uplinks_up[self.rack_of(src)])
            links.append(self.uplinks_down[self.rack_of(dst)])
        links.append(self.ingress[dst])
        return links

    def uplink_busy_s(self) -> float:
        return sum(
            l.busy_seconds for l in self.uplinks_up + self.uplinks_down
        )

    def n_uplinks(self) -> int:
        return len(self.uplinks_up) + len(self.uplinks_down)


class FluidFlow:
    """One in-flight transfer under the fluid-flow (progressive-filling)
    model: ``nbytes`` of payload crossing ``hops`` — a list of
    ``(Link, weight)`` pairs, where ``weight`` is the link capacity the
    flow consumes per payload byte/s (1.0 for a NIC hop; 2.0 for the
    coordinator NIC on the relay route, which carries every byte twice).

    ``rate`` is the current max-min fair payload rate in bytes/s; it is
    re-assigned by :meth:`FluidNetwork._reallocate` every time any flow
    starts or finishes anywhere on the fabric.
    """

    __slots__ = (
        "fid", "src", "dst", "nbytes", "remaining", "hops", "rate",
        "on_done", "t_start",
    )

    def __init__(self, fid, src, dst, nbytes, hops, on_done, t_start):
        self.fid = fid
        self.src = src
        self.dst = dst
        self.nbytes = int(nbytes)
        self.remaining = float(nbytes)
        self.hops = hops
        self.rate = 0.0
        self.on_done = on_done
        self.t_start = t_start


class FluidNetwork:
    """Progressive-filling (max-min fair) fluid-flow engine over a
    :class:`NetworkModel` topology.

    The store-and-forward model reserves each link back-to-back: a
    transfer holds the whole link for ``bytes/bandwidth`` seconds and
    later transfers queue behind it.  Real fabrics multiplex: N flows
    sharing a link each progress at roughly ``capacity / N`` and every
    flow's rate changes whenever any flow starts or finishes.  This
    class models exactly that:

    * :meth:`start` registers a flow over the topology's path
      (source NIC, any shared uplinks, destination NIC) and re-rates
      **all** active flows by weighted progressive filling: repeatedly
      grant every unfrozen flow the smallest per-link fair share,
      freeze the flows crossing the bottleneck link, subtract their
      consumption, and continue — the textbook max-min fair water
      filling, with per-hop weights so the relay route's coordinator
      NIC (2 bytes crossed per payload byte) is charged honestly.
    * The engine is clock-agnostic: the owning simulator injects
      ``now()`` and ``post(t, fn)`` and the network posts itself one
      ``transfer_progress`` event at the earliest flow completion;
      stale events (rates changed since) are invalidated by a
      generation counter.
    * Byte and busy accounting land on the *same* :class:`Link`
      objects the store-and-forward path uses (``bytes_total``, and
      ``busy_seconds`` as utilization-integrated time), so
      ``SimResult.uplink_busy_s`` / rack byte counters read
      identically from either engine.

    Conservation is tracked first-class: ``bytes_injected`` equals
    ``bytes_delivered`` plus the payload of the flows still active at
    every instant (the invariant suite pins this).
    """

    def __init__(
        self,
        topo: NetworkModel,
        *,
        now: Callable[[], float],
        post: Callable[[float, Callable[[], None]], None],
    ) -> None:
        self.topo = topo
        self._now = now
        self._post = post
        self.flows: dict[int, FluidFlow] = {}
        # id(Link) -> {fid: weight} for active flows; id() keys because
        # the same Link object is shared with the reservation path.
        self._link_flows: dict[int, dict[int, float]] = {}
        self._links: dict[int, Link] = {}
        self._fid = itertools.count(1)
        self._t_last = 0.0
        self._gen = 0
        self.bytes_injected = 0
        self.bytes_delivered = 0
        self.flows_started = 0
        self.flows_completed = 0
        # Peak concurrent flows (sizing/diagnostic, shown in benches).
        self.max_concurrent = 0

    # -- public API ---------------------------------------------------------

    def start(
        self,
        src: Optional[int],
        dst: int,
        nbytes: int,
        on_done: Callable[[float], None],
        *,
        relay: bool = False,
    ) -> Optional[int]:
        """Begin a transfer; ``on_done(t)`` fires when the last byte
        lands.  Same-node copies complete immediately (synchronously).
        Returns the flow id, or None for the degenerate instant copy.
        """
        t = self._now()
        self._advance(t)
        if relay:
            hops: list[tuple[Link, float]] = []
            if src is not None and src != dst:
                hops.append((self.topo.egress[src], 1.0))
            hops.append((self.topo.coordinator, 2.0))
            hops.append((self.topo.ingress[dst], 1.0))
        else:
            hops = [(l, 1.0) for l in self.topo.path(src, dst)]
            self.topo.account_rack(src, dst, nbytes)
        if not hops or nbytes <= 0:
            on_done(t)
            return None
        fid = next(self._fid)
        flow = FluidFlow(fid, src, dst, nbytes, hops, on_done, t)
        self.flows[fid] = flow
        for link, w in hops:
            lid = id(link)
            self._links[lid] = link
            self._link_flows.setdefault(lid, {})[fid] = w
            link.bytes_total += int(nbytes * w)
        self.bytes_injected += int(nbytes)
        self.flows_started += 1
        self.max_concurrent = max(self.max_concurrent, len(self.flows))
        self._reallocate()
        self._schedule()
        return fid

    @property
    def n_active(self) -> int:
        return len(self.flows)

    def in_flight_bytes(self) -> float:
        return sum(f.remaining for f in self.flows.values())

    def conservation_error(self) -> float:
        """``injected - delivered - sum(active flow payloads)``; exactly
        0 at all times — non-zero means a flow was lost, registered
        twice, or delivered twice.  (``in_flight_bytes`` is the
        *remaining* payload, which mid-flight differs from the active
        payload by the bytes already moved.)"""
        return (
            self.bytes_injected
            - self.bytes_delivered
            - sum(f.nbytes for f in self.flows.values())
        )

    def link_rate(self, link: Link) -> float:
        """Current aggregate consumption on ``link`` (bytes/s)."""
        fl = self._link_flows.get(id(link), {})
        return sum(self.flows[fid].rate * w for fid, w in fl.items())

    # -- engine internals ---------------------------------------------------

    def _advance(self, t: float) -> None:
        """Progress every flow to time ``t`` and complete the finished
        ones (their callbacks may start new flows re-entrantly — state
        is consistent before any callback fires)."""
        dt = t - self._t_last
        if dt <= 0.0 or not self.flows:
            self._t_last = max(self._t_last, t)
            return
        for lid, fl in self._link_flows.items():
            if not fl:
                continue
            link = self._links[lid]
            cap = link.gb_s * _GB
            used = sum(self.flows[fid].rate * w for fid, w in fl.items())
            link.busy_seconds += (min(used, cap) / cap) * dt
        done: list[FluidFlow] = []
        for f in self.flows.values():
            f.remaining -= f.rate * dt
            if f.remaining <= _EPS_BYTES:
                done.append(f)
        self._t_last = t
        if not done:
            return
        done.sort(key=lambda f: f.fid)  # deterministic completion order
        for f in done:
            self._remove(f)
        self._reallocate()
        for f in done:
            self.bytes_delivered += f.nbytes
            self.flows_completed += 1
            f.on_done(t)

    def _remove(self, flow: FluidFlow) -> None:
        self.flows.pop(flow.fid, None)
        for link, _w in flow.hops:
            fl = self._link_flows.get(id(link))
            if fl is not None:
                fl.pop(flow.fid, None)

    def _reallocate(self) -> None:
        """Weighted progressive filling: assign every active flow its
        max-min fair payload rate.  O(bottlenecks x links x flows) —
        flows on the fabric at once are bounded by in-flight staging
        copies, so this stays cheap even at fleet scale."""
        if not self.flows:
            return
        residual: dict[int, float] = {}
        for lid, fl in self._link_flows.items():
            if fl:
                residual[lid] = self._links[lid].gb_s * _GB
        todo = set(self.flows)
        while todo:
            r_star: Optional[float] = None
            for lid, fl in self._link_flows.items():
                w_tot = 0.0
                for fid, w in fl.items():
                    if fid in todo:
                        w_tot += w
                if w_tot <= 0.0:
                    continue
                share = residual[lid] / w_tot
                if r_star is None or share < r_star:
                    r_star = share
            if r_star is None:  # pragma: no cover - defensive
                for fid in todo:
                    self.flows[fid].rate = 0.0
                break
            bound = r_star * (1.0 + 1e-12)
            frozen: set[int] = set()
            for lid, fl in self._link_flows.items():
                w_tot = 0.0
                for fid, w in fl.items():
                    if fid in todo:
                        w_tot += w
                if w_tot <= 0.0:
                    continue
                if residual[lid] / w_tot <= bound:
                    for fid in fl:
                        if fid in todo:
                            frozen.add(fid)
            for fid in frozen:
                f = self.flows[fid]
                f.rate = r_star
                for link, w in f.hops:
                    lid = id(link)
                    residual[lid] = max(residual[lid] - r_star * w, 0.0)
            todo -= frozen

    def _schedule(self) -> None:
        """Post the next ``transfer_progress`` event at the earliest
        flow completion; the generation counter invalidates any event
        posted before the latest re-rate."""
        self._gen += 1
        if not self.flows:
            return
        t_next = min(
            self._t_last + f.remaining / f.rate
            for f in self.flows.values()
            if f.rate > 0.0
        )
        gen = self._gen
        self._post(t_next, lambda: self._on_timer(gen))

    def _on_timer(self, gen: int) -> None:
        if gen != self._gen:
            return  # superseded by a later re-rate
        self._advance(self._now())
        self._schedule()


def build_network(
    kind: str,
    n_nodes: int,
    link_gb_s: float,
    *,
    rack_size: int = 4,
    oversubscription: float = 4.0,
    coordinator_gb_s: Optional[float] = None,
) -> NetworkModel:
    """Factory behind ``SimConfig.network``.

    ``"flat"`` — non-blocking single tier (default, seed-compatible
    plus source-NIC serialization); ``"fat_tree"`` (aliases
    ``"fat-tree"``, ``"fattree"``) — two-tier oversubscribed tree.
    """
    normalized = kind.lower().replace("-", "_").replace(" ", "_")
    if normalized == "flat":
        return FlatNetwork(
            n_nodes, link_gb_s, coordinator_gb_s=coordinator_gb_s
        )
    if normalized in ("fat_tree", "fattree"):
        return FatTreeNetwork(
            n_nodes,
            link_gb_s,
            rack_size=rack_size,
            oversubscription=oversubscription,
            coordinator_gb_s=coordinator_gb_s,
        )
    raise ValueError(f"unknown network model {kind!r}")
