"""Demand-driven Manager (paper §III-B, Fig 4) with fault tolerance.

The Manager has the overall view of the runtime: it instantiates the
abstract workflow, tracks inter-stage dependencies, and leases stage
instances to Workers demand-driven — each Worker holds at most
``window`` leases and requests more as leases complete (the paper's
*Window size*, §V-F).

Beyond the paper, the Manager provides the fault-tolerance required for
thousand-node deployments:

* **heartbeats** — a Worker that stops reporting is declared dead and
  its outstanding leases return to the queue (chunk processing is
  idempotent, so re-execution is safe);
* **straggler backup tasks** — at the tail of a run, outstanding leases
  are duplicated onto idle Workers and the first completion wins;
* **elastic membership** — Workers may register/deregister mid-run;
  the lease queue simply redistributes.

The Manager is transport-agnostic: in a single process Worker objects
are registered directly; on a cluster the same protocol runs over a
:mod:`repro.transport` MessageBus — a ``ManagerEndpoint`` serves the
lease/complete/heartbeat/region-pull RPCs and each remote worker
appears here as a ``WorkerProxy``.  With ``ManagerConfig.journal_path``
set, placement and lease state are write-ahead journaled so a restarted
Manager rehydrates instead of restarting the workflow.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .workflow import ConcreteWorkflow, StageInstance
from .worker import WorkerRuntime
from ..staging import (
    DirectoryService,
    PlacementDirectory,
    PlacementPolicy,
    op_key,
    select_lease,
)
from ..staging.tiers import RegionKey, sizeof

__all__ = ["Manager", "ManagerConfig"]


@dataclass
class ManagerConfig:
    window: int = 4                  # leases in flight per worker
    heartbeat_timeout: float = 60.0  # seconds without progress => dead
    backup_tasks: bool = True       # duplicate tail leases
    poll_interval: float = 0.01
    # Cluster-level locality-aware lease placement (repro.staging): lease
    # a dependent stage instance to the worker already holding the
    # largest fraction of its input bytes, demand-driven otherwise.
    locality_aware: bool = False
    placement: PlacementPolicy = field(default_factory=PlacementPolicy)
    directory: Optional[PlacementDirectory] = None  # default: fresh one
    # Failover-surviving placement state: when set, the directory is
    # wrapped in a journaled DirectoryService at this path.  A Manager
    # constructed over a path that already holds a journal *rehydrates*:
    # holder maps, completed stages, and the pending-lease queue are
    # replayed so a restarted coordinator resumes instead of restarting.
    journal_path: Optional[str] = None
    snapshot_every: int = 512        # journal appends between checkpoints


@dataclass
class _WorkerState:
    runtime: WorkerRuntime
    leases: set[int] = field(default_factory=set)
    last_heartbeat: float = field(default_factory=time.monotonic)
    dead: bool = False


class Manager:
    def __init__(self, workflow: ConcreteWorkflow, cfg: ManagerConfig | None = None):
        self.cw = workflow
        self.cfg = cfg or ManagerConfig()
        self._lock = threading.RLock()
        self._workers: dict[int, _WorkerState] = {}
        self._pending: deque[StageInstance] = deque()
        self._stage_done: set[int] = set()
        self._stage_outputs: dict[int, dict[str, Any]] = {}
        self._dup_issued: set[int] = set()
        self.recovered_leases = 0
        self.duplicated_leases = 0
        # Cluster placement metadata + locality accounting.  With a
        # journal path the directory becomes a DirectoryService whose
        # mutations are write-ahead logged; opening an existing journal
        # rehydrates holder maps and the lease ledger (failover).
        if self.cfg.journal_path is not None:
            self.directory: PlacementDirectory = DirectoryService(
                self.cfg.journal_path,
                self.cfg.directory,
                snapshot_every=self.cfg.snapshot_every,
            )
            for uid in self.directory.completed:
                if uid in self.cw.stage_instances:
                    self._stage_done.add(uid)
        else:
            self.directory = self.cfg.directory or PlacementDirectory()
        self.placement_local = 0       # dependent leased where its data is
        self.placement_remote = 0      # dependent leased elsewhere
        self.staged_bytes_avoided = 0  # inputs not re-sent: already staged
        self._done_event = threading.Event()
        self._monitor: Optional[threading.Thread] = None
        self._stop_monitor = False

    # -- membership -------------------------------------------------------

    def register_worker(self, runtime: WorkerRuntime) -> None:
        runtime.on_stage_complete = self._make_completion_cb(runtime.worker_id)
        runtime.on_heartbeat = self._heartbeat  # per-op liveness pings
        # Region pull path: the StagingAgent prefetches completed
        # upstream outputs, and lanes re-pull inputs evicted under soft
        # tier budgets (worker._gather_inputs fallback).  fetch_regions
        # is the batched flavor: one round-trip per coalesced key batch.
        runtime.fetch_region = self._fetch_region
        runtime.fetch_regions = self._fetch_regions
        # Keep the directory honest: a region falling off the worker's
        # bottom tier is no longer a replica there (lease placement and
        # the eviction preference below both read this map).
        wid = runtime.worker_id
        runtime.store.on_drop = (
            lambda key, _wid=wid: self.directory.evict(_wid, key)
        )
        # Replication-aware eviction: under budget pressure the worker's
        # host tier sheds regions the directory shows replicated on
        # another worker before sole copies (policy knob).
        if self.cfg.placement.replication_aware_eviction:
            try:
                host = runtime.store.tier("host")
            except KeyError:
                host = None
            if host is not None:
                host.replicated = (
                    lambda key, _wid=wid: self.directory.replicated_elsewhere(
                        _wid, key
                    )
                )
        with self._lock:
            # A relaunched worker re-registering its id must not orphan
            # the old incarnation's in-flight leases: recover them first
            # (chunk processing is idempotent), and drop the dead
            # incarnation's replicas from the directory.
            old = self._workers.get(wid)
            if old is not None:
                for uid in old.leases:
                    if uid not in self._stage_done:
                        self.recovered_leases += 1
                        self._push_pending_locked(self.cw.stage_instances[uid])
                self.directory.drop_worker(wid)
            self._workers[wid] = _WorkerState(runtime=runtime)
            self._dispatch_all_locked()

    def _heartbeat(self, worker_id: int) -> None:
        with self._lock:
            st = self._workers.get(worker_id)
            if st is not None:
                st.last_heartbeat = time.monotonic()
                if st.dead and st.runtime.alive:
                    # A fresh heartbeat after a reap proves the "dead"
                    # worker was merely slow (one op outlasted the
                    # window): rejoin it.  Its leases were already
                    # recovered; chunk processing is idempotent.
                    st.dead = False
                    self._dispatch_all_locked()

    def deregister_worker(self, worker_id: int) -> None:
        """Elastic scale-down: return the worker's leases to the queue."""
        with self._lock:
            st = self._workers.pop(worker_id, None)
            if st is None:
                return
            for uid in st.leases:
                if uid not in self._stage_done:
                    self.recovered_leases += 1
                    self._push_pending_locked(self.cw.stage_instances[uid])
            self.directory.drop_worker(worker_id)
            self._dispatch_all_locked()

    def _push_pending_locked(self, si: StageInstance) -> None:
        self._pending.append(si)
        svc = self._journal_svc()
        if svc is not None:
            svc.note_pending(si.uid)

    # -- execution -----------------------------------------------------------

    def run(self, timeout: float = 120.0) -> bool:
        """Lease everything and block until the workflow completes."""
        with self._lock:
            # One membership set up front: at fig14 scale (~37k ready
            # instances) rebuilding it per stage would be O(P^2).
            queued = {p.uid for p in self._pending}
            queued.update(
                uid for w in self._workers.values() for uid in w.leases
            )
            for si in self.cw.ready_stage_instances(self._stage_done):
                if si.uid not in queued:
                    queued.add(si.uid)
                    self._push_pending_locked(si)
            self._dispatch_all_locked()
        self._stop_monitor = False
        self._monitor = threading.Thread(target=self._monitor_loop, daemon=True)
        self._monitor.start()
        ok = self._done_event.wait(timeout=timeout)
        self._stop_monitor = True
        self._monitor.join(timeout=2.0)
        return ok

    def progress(self) -> tuple[int, int]:
        with self._lock:
            total = sum(
                1 for uid in self.cw.stage_instances if uid not in self._clone_map()
            )
            return len(self._stage_done - set(self._clone_map())), total

    def stage_outputs(self, uid: int) -> dict[str, Any]:
        with self._lock:
            return self._stage_outputs.get(uid, {})

    # -- internals ---------------------------------------------------------------

    def _clone_map(self) -> dict[int, int]:
        return getattr(self, "_clones_of", {})

    def _make_completion_cb(self, worker_id: int):
        def cb(si: StageInstance, outputs: dict[str, Any]) -> None:
            self._on_stage_complete(worker_id, si, outputs)

        return cb

    def _on_stage_complete(
        self, worker_id: int, si: StageInstance, outputs: dict[str, Any]
    ) -> None:
        with self._lock:
            st = self._workers.get(worker_id)
            if st is not None:
                st.last_heartbeat = time.monotonic()
            clones_of = self._clone_map()
            primary_uid = clones_of.get(si.uid, si.uid)
            if primary_uid in self._stage_done:
                return  # a backup twin already completed this lease
            self._stage_done.add(primary_uid)
            if si.uid != primary_uid:
                self._stage_done.add(si.uid)
            self._stage_outputs[primary_uid] = outputs
            for wst in self._workers.values():
                wst.leases.discard(si.uid)
                wst.leases.discard(primary_uid)
                # Cancel twins on other workers.
                for c_uid, p_uid in clones_of.items():
                    if p_uid == primary_uid and c_uid in wst.leases:
                        wst.runtime.cancel_stage(c_uid)
                        wst.leases.discard(c_uid)
            primary = self.cw.stage_instances[primary_uid]
            # The completing worker now holds this stage's sink outputs:
            # record placements so dispatch can route dependents to it.
            sinks = set(primary.stage.sinks())
            for oi in primary.op_instances:
                if oi.op.name in sinks and outputs.get(oi.op.name) is not None:
                    if si.uid != primary_uid and st is not None:
                        # A backup twin finished: its store holds the
                        # outputs under the clone's op uids.  Alias them
                        # under the primary keys (same objects, no copy)
                        # so the placement below is actually serviceable.
                        st.runtime.provide_input(oi.uid, outputs[oi.op.name])
                    self.directory.record(
                        worker_id, op_key(oi.uid), sizeof(outputs[oi.op.name])
                    )
            # Journal the completion only AFTER the sink placements: a
            # crash in between must rehydrate the stage as *incomplete*
            # (idempotent re-run) rather than as done-with-no-holders,
            # which would wedge push-mode dependents.
            svc = self._journal_svc()
            if svc is not None:
                svc.note_complete(primary_uid)
            # Unlock downstream stage instances and forward their inputs.
            for dep_uid in primary.dependents:
                dsi = self.cw.stage_instances[dep_uid]
                if dsi.deps.issubset(self._stage_done) and dep_uid not in self._stage_done:
                    already = any(
                        dep_uid in w.leases for w in self._workers.values()
                    ) or any(p.uid == dep_uid for p in self._pending)
                    if not already:
                        self._push_pending_locked(dsi)
            self._dispatch_all_locked()
            self._check_done_locked()

    def _dispatch_all_locked(self) -> None:
        live = {
            wid: st
            for wid, st in self._workers.items()
            if not st.dead and st.runtime.alive
        }
        if self.cfg.locality_aware:
            self._dispatch_locality_locked(live)
        else:
            for wid, st in live.items():
                while len(st.leases) < self.cfg.window and self._pending:
                    self._lease_locked(wid, st, self._pending.popleft())
        if self.cfg.backup_tasks and not self._pending:
            self._issue_backups_locked()

    def _dispatch_locality_locked(
        self, live: dict[int, _WorkerState]
    ) -> None:
        """Locality-aware lease placement over the pending deque.

        First pass may *defer* a stage whose input bytes live on another
        worker that still has window slack; the second pass is purely
        work-conserving so nothing starves (demand-driven fallback).
        """
        for allow_defer in (True, False):
            progress = True
            while progress and self._pending:
                progress = False
                slack = {
                    wid
                    for wid, st in live.items()
                    if len(st.leases) < self.cfg.window
                }
                if not slack:
                    return
                for wid, st in live.items():
                    if len(st.leases) >= self.cfg.window or not self._pending:
                        continue
                    idx = select_lease(
                        self._pending,
                        wid,
                        self.directory,
                        self._input_keys,
                        self.cfg.placement,
                        workers_with_slack=slack,
                        allow_defer=allow_defer,
                    )
                    if idx is None:
                        continue
                    si = self._pending[idx]
                    del self._pending[idx]
                    self._lease_locked(wid, st, si)
                    progress = True

    def _lease_locked(
        self, wid: int, st: _WorkerState, si: StageInstance
    ) -> None:
        keys = self._input_keys(si)
        if keys:
            best = self.directory.best_worker(keys)
            if best is not None and best[1] > 0.0:
                if best[0] == wid:
                    self.placement_local += 1
                else:
                    self.placement_remote += 1
        st.leases.add(si.uid)
        svc = self._journal_svc()
        if svc is not None:
            svc.note_lease(si.uid, wid)
        self._forward_upstream_outputs(st.runtime, si)
        st.runtime.submit_stage(si)

    def _journal_svc(self) -> Optional[DirectoryService]:
        d = self.directory
        return d if isinstance(d, DirectoryService) else None

    def _input_keys(self, si: StageInstance) -> list[RegionKey]:
        """Region keys of a stage instance's cross-stage inputs."""
        local = {oi.uid for oi in si.op_instances}
        return [
            op_key(dep_uid)
            for oi in si.op_instances
            for dep_uid in oi.deps
            if dep_uid not in local
        ]

    def _fetch_region(self, key: RegionKey) -> Any:
        """Region pull: output of a completed upstream op, or None.

        The Manager's own output copy is tried first; after a failover
        rehydration that copy is gone, so the pull falls back to a
        worker the placement directory records as a holder (region-pull
        RPC via the worker handle).  The holder RPCs run *outside* the
        Manager lock: a slow or hung holder must not stall heartbeats
        and dispatch for every other worker.
        """
        if not (isinstance(key, tuple) and len(key) == 2 and key[0] == "op"):
            return None
        with self._lock:
            oi = self.cw.op_instances.get(key[1])
            if oi is None:
                return None
            outputs = self._stage_outputs.get(oi.stage_instance.uid)
            if outputs and oi.op.name in outputs:
                return outputs.get(oi.op.name)
            holders = self._holder_runtimes_locked(key)
        for rt in holders:
            value = rt.pull_region(key)
            if value is not None:
                return value
        return None

    def _fetch_regions(self, keys: list) -> list:
        """Batched region pull: one round-trip serves a whole key batch
        (StagingAgent coalescing / SocketBus ``fetch_regions`` RPC)."""
        return [self._fetch_region(key) for key in keys]

    def _holder_runtimes_locked(
        self, key: RegionKey, exclude: Optional[int] = None
    ) -> list:
        """Live worker handles the directory names as holders of ``key``."""
        out = []
        for wid in self.directory.holders(key):
            if wid == exclude:
                continue
            st = self._workers.get(wid)
            if st is not None and not st.dead and st.runtime.alive:
                out.append(st.runtime)
        return out

    def _pull_from_holder_locked(
        self, key: RegionKey, exclude: Optional[int] = None
    ) -> Any:
        """Synchronous holder pull for the (rare) rehydration push path.

        Runs under the Manager lock — only reached when forwarding to an
        agent-less worker after a failover; proxies cap the RPC timeout
        so a hung holder bounds, not wedges, the control plane.
        """
        for rt in self._holder_runtimes_locked(key, exclude=exclude):
            value = rt.pull_region(key)
            if value is not None:
                return value
        return None

    def _forward_upstream_outputs(self, rt: WorkerRuntime, si: StageInstance) -> None:
        """Provide cross-stage inputs (sink op outputs of upstream stages).

        Workers running a StagingAgent get the *pull* flavor: inputs not
        already staged are left for the agent to prefetch asynchronously
        (submit_stage enqueues the requests), overlapping the copy with
        whatever the lanes are executing.  Agent-less workers get the
        classic synchronous push.

        Delivery is one batched ``forward_inputs`` round-trip per lease:
        the worker marks inputs already staged there (skip-copy; the
        savings are accounted here) and ingests the pushed values —
        on a SocketBus that is a single frame instead of a per-
        dependency mark/provide conversation.
        """
        lazy = getattr(rt, "agent", None) is not None
        items: list[tuple[int, Any, bool]] = []
        sizes: dict[int, int] = {}
        for oi in si.op_instances:
            for dep_uid in oi.deps:
                if dep_uid not in self.cw.op_instances:
                    continue
                dep_oi = self.cw.op_instances[dep_uid]
                if dep_oi.stage_instance.uid == si.uid:
                    continue
                up_uid = dep_oi.stage_instance.uid
                up_outputs = self._stage_outputs.get(up_uid, {})
                if dep_oi.op.name in up_outputs:
                    value = up_outputs[dep_oi.op.name]
                elif up_uid in self._stage_done:
                    # Rehydrated Manager: the output payload died with
                    # the old coordinator.  Lazy workers pull it through
                    # fetch_region (which consults directory holders);
                    # push-mode workers need it refetched right now.
                    key = op_key(dep_uid)
                    value = (
                        None
                        if lazy
                        else self._pull_from_holder_locked(
                            key, exclude=rt.worker_id
                        )
                    )
                else:
                    continue  # upstream genuinely not finished yet
                sizes[dep_uid] = (
                    sizeof(value)
                    if value is not None
                    else max(
                        self.directory.holders(op_key(dep_uid)).values(),
                        default=0,
                    )
                )
                push = not lazy and value is not None
                items.append((dep_uid, value if push else None, push))
        if not items:
            return
        for uid in rt.forward_inputs(items):
            # Already staged on that worker (it ran the upstream, or its
            # agent prefetched it): the copy was skipped — account it.
            self.staged_bytes_avoided += sizes.get(uid, 0)

    def _issue_backups_locked(self) -> None:
        clones_of = getattr(self, "_clones_of", None)
        if clones_of is None:
            clones_of = self._clones_of = {}
        idle = [
            st
            for st in self._workers.values()
            if not st.dead and st.runtime.alive and not st.leases
        ]
        if not idle:
            return
        outstanding: list[StageInstance] = []
        for st in self._workers.values():
            for uid in st.leases:
                if (
                    uid not in self._stage_done
                    and uid not in self._dup_issued
                    and uid not in clones_of
                ):
                    outstanding.append(self.cw.stage_instances[uid])
        for st, si in zip(idle, outstanding):
            self._dup_issued.add(si.uid)
            self.duplicated_leases += 1
            clone = self.cw._new_stage_instance(si.chunk, si.stage)  # noqa: SLF001
            # Mirror the original's cross-stage input edges so the twin
            # computes on the same upstream outputs (a bare re-instance
            # would run its source ops on the raw chunk payload).
            local = {o.uid for o in si.op_instances}
            orig_by_name = {o.op.name: o for o in si.op_instances}
            for c_oi in clone.op_instances:
                c_oi.deps |= orig_by_name[c_oi.op.name].deps - local
            clones_of[clone.uid] = si.uid
            st.leases.add(clone.uid)
            self._forward_upstream_outputs(st.runtime, clone)
            st.runtime.submit_stage(clone)

    def _check_done_locked(self) -> None:
        clones = set(self._clone_map())
        for uid in self.cw.stage_instances:
            if uid in clones:
                continue
            if uid not in self._stage_done:
                return
        self._done_event.set()

    def _monitor_loop(self) -> None:
        """Heartbeat watchdog: reap dead workers, re-lease their work."""
        while not self._stop_monitor and not self._done_event.is_set():
            time.sleep(self.cfg.poll_interval)
            now = time.monotonic()
            with self._lock:
                any_live = any(
                    not st.dead and st.runtime.alive
                    for st in self._workers.values()
                )
                for wid, st in self._workers.items():
                    if st.dead:
                        # Last-resort rejoin: every worker has been
                        # reaped yet this one's runtime reports alive.
                        # Without it a cluster whose every (healthy but
                        # slow) worker was slandered wedges with work
                        # pending and nobody to run it.  With other
                        # live workers, exclusion stands — a genuinely
                        # wedged worker must not be re-leased work; it
                        # rejoins only via a fresh heartbeat
                        # (_heartbeat), which proves progress.
                        if not any_live and st.runtime.alive:
                            st.dead = False
                            st.last_heartbeat = now
                            any_live = True
                        continue
                    inflight = bool(st.leases)
                    expired = (
                        now - st.last_heartbeat > self.cfg.heartbeat_timeout
                    )
                    if not st.runtime.alive or (inflight and expired):
                        st.dead = True
                        self.directory.drop_worker(wid)
                        for uid in st.leases:
                            if uid not in self._stage_done:
                                self.recovered_leases += 1
                                self._push_pending_locked(
                                    self.cw.stage_instances[uid]
                                )
                        st.leases.clear()
                self._dispatch_all_locked()
                self._check_done_locked()
