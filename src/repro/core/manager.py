"""Demand-driven Manager (paper §III-B, Fig 4) with fault tolerance.

The Manager has the overall view of the runtime: it instantiates the
abstract workflow, tracks inter-stage dependencies, and leases stage
instances to Workers demand-driven — each Worker holds at most
``window`` leases and requests more as leases complete (the paper's
*Window size*, §V-F).

Beyond the paper, the Manager provides the fault-tolerance required for
thousand-node deployments:

* **heartbeats** — a Worker that stops reporting is declared dead and
  its outstanding leases return to the queue (chunk processing is
  idempotent, so re-execution is safe);
* **straggler backup tasks** — at the tail of a run, outstanding leases
  are duplicated onto idle Workers and the first completion wins;
* **elastic membership** — Workers may register/deregister mid-run;
  the lease queue simply redistributes.

In a single process the Worker objects are invoked directly; on a
cluster the same protocol runs over MPI/gRPC — the Manager class is
transport-agnostic (``transport`` hooks).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .workflow import ConcreteWorkflow, StageInstance
from .worker import WorkerRuntime
from ..staging import PlacementDirectory, PlacementPolicy, op_key, select_lease
from ..staging.tiers import RegionKey, sizeof

__all__ = ["Manager", "ManagerConfig"]


@dataclass
class ManagerConfig:
    window: int = 4                  # leases in flight per worker
    heartbeat_timeout: float = 60.0  # seconds without progress => dead
    backup_tasks: bool = True       # duplicate tail leases
    poll_interval: float = 0.01
    # Cluster-level locality-aware lease placement (repro.staging): lease
    # a dependent stage instance to the worker already holding the
    # largest fraction of its input bytes, demand-driven otherwise.
    locality_aware: bool = False
    placement: PlacementPolicy = field(default_factory=PlacementPolicy)
    directory: Optional[PlacementDirectory] = None  # default: fresh one


@dataclass
class _WorkerState:
    runtime: WorkerRuntime
    leases: set[int] = field(default_factory=set)
    last_heartbeat: float = field(default_factory=time.monotonic)
    dead: bool = False


class Manager:
    def __init__(self, workflow: ConcreteWorkflow, cfg: ManagerConfig | None = None):
        self.cw = workflow
        self.cfg = cfg or ManagerConfig()
        self._lock = threading.RLock()
        self._workers: dict[int, _WorkerState] = {}
        self._pending: deque[StageInstance] = deque()
        self._stage_done: set[int] = set()
        self._stage_outputs: dict[int, dict[str, Any]] = {}
        self._dup_issued: set[int] = set()
        self.recovered_leases = 0
        self.duplicated_leases = 0
        # Cluster placement metadata + locality accounting.
        self.directory = self.cfg.directory or PlacementDirectory()
        self.placement_local = 0       # dependent leased where its data is
        self.placement_remote = 0      # dependent leased elsewhere
        self.staged_bytes_avoided = 0  # inputs not re-sent: already staged
        self._done_event = threading.Event()
        self._monitor: Optional[threading.Thread] = None
        self._stop_monitor = False

    # -- membership -------------------------------------------------------

    def register_worker(self, runtime: WorkerRuntime) -> None:
        runtime.on_stage_complete = self._make_completion_cb(runtime.worker_id)
        runtime.on_heartbeat = self._heartbeat  # per-op liveness pings
        # Region pull path: the StagingAgent prefetches completed
        # upstream outputs, and lanes re-pull inputs evicted under soft
        # tier budgets (worker._gather_inputs fallback).
        runtime.fetch_region = self._fetch_region
        # Keep the directory honest: a region falling off the worker's
        # bottom tier is no longer a replica there (lease placement and
        # the eviction preference below both read this map).
        wid = runtime.worker_id
        runtime.store.on_drop = (
            lambda key, _wid=wid: self.directory.evict(_wid, key)
        )
        # Replication-aware eviction: under budget pressure the worker's
        # host tier sheds regions the directory shows replicated on
        # another worker before sole copies (policy knob).
        if self.cfg.placement.replication_aware_eviction:
            try:
                host = runtime.store.tier("host")
            except KeyError:
                host = None
            if host is not None:
                host.replicated = (
                    lambda key, _wid=wid: self.directory.replicated_elsewhere(
                        _wid, key
                    )
                )
        with self._lock:
            self._workers[runtime.worker_id] = _WorkerState(runtime=runtime)

    def _heartbeat(self, worker_id: int) -> None:
        with self._lock:
            st = self._workers.get(worker_id)
            if st is not None:
                st.last_heartbeat = time.monotonic()
                if st.dead and st.runtime.alive:
                    # A fresh heartbeat after a reap proves the "dead"
                    # worker was merely slow (one op outlasted the
                    # window): rejoin it.  Its leases were already
                    # recovered; chunk processing is idempotent.
                    st.dead = False
                    self._dispatch_all_locked()

    def deregister_worker(self, worker_id: int) -> None:
        """Elastic scale-down: return the worker's leases to the queue."""
        with self._lock:
            st = self._workers.pop(worker_id, None)
            if st is None:
                return
            for uid in st.leases:
                if uid not in self._stage_done:
                    self.recovered_leases += 1
                    self._pending.append(self.cw.stage_instances[uid])
            self.directory.drop_worker(worker_id)
            self._dispatch_all_locked()

    # -- execution -----------------------------------------------------------

    def run(self, timeout: float = 120.0) -> bool:
        """Lease everything and block until the workflow completes."""
        with self._lock:
            self._pending.extend(self.cw.ready_stage_instances(self._stage_done))
            self._dispatch_all_locked()
        self._stop_monitor = False
        self._monitor = threading.Thread(target=self._monitor_loop, daemon=True)
        self._monitor.start()
        ok = self._done_event.wait(timeout=timeout)
        self._stop_monitor = True
        self._monitor.join(timeout=2.0)
        return ok

    def progress(self) -> tuple[int, int]:
        with self._lock:
            total = sum(
                1 for uid in self.cw.stage_instances if uid not in self._clone_map()
            )
            return len(self._stage_done - set(self._clone_map())), total

    def stage_outputs(self, uid: int) -> dict[str, Any]:
        with self._lock:
            return self._stage_outputs.get(uid, {})

    # -- internals ---------------------------------------------------------------

    def _clone_map(self) -> dict[int, int]:
        return getattr(self, "_clones_of", {})

    def _make_completion_cb(self, worker_id: int):
        def cb(si: StageInstance, outputs: dict[str, Any]) -> None:
            self._on_stage_complete(worker_id, si, outputs)

        return cb

    def _on_stage_complete(
        self, worker_id: int, si: StageInstance, outputs: dict[str, Any]
    ) -> None:
        with self._lock:
            st = self._workers.get(worker_id)
            if st is not None:
                st.last_heartbeat = time.monotonic()
            clones_of = self._clone_map()
            primary_uid = clones_of.get(si.uid, si.uid)
            if primary_uid in self._stage_done:
                return  # a backup twin already completed this lease
            self._stage_done.add(primary_uid)
            if si.uid != primary_uid:
                self._stage_done.add(si.uid)
            self._stage_outputs[primary_uid] = outputs
            for wst in self._workers.values():
                wst.leases.discard(si.uid)
                wst.leases.discard(primary_uid)
                # Cancel twins on other workers.
                for c_uid, p_uid in clones_of.items():
                    if p_uid == primary_uid and c_uid in wst.leases:
                        wst.runtime.cancel_stage(c_uid)
                        wst.leases.discard(c_uid)
            primary = self.cw.stage_instances[primary_uid]
            # The completing worker now holds this stage's sink outputs:
            # record placements so dispatch can route dependents to it.
            sinks = set(primary.stage.sinks())
            for oi in primary.op_instances:
                if oi.op.name in sinks and outputs.get(oi.op.name) is not None:
                    if si.uid != primary_uid and st is not None:
                        # A backup twin finished: its store holds the
                        # outputs under the clone's op uids.  Alias them
                        # under the primary keys (same objects, no copy)
                        # so the placement below is actually serviceable.
                        st.runtime.provide_input(oi.uid, outputs[oi.op.name])
                    self.directory.record(
                        worker_id, op_key(oi.uid), sizeof(outputs[oi.op.name])
                    )
            # Unlock downstream stage instances and forward their inputs.
            for dep_uid in primary.dependents:
                dsi = self.cw.stage_instances[dep_uid]
                if dsi.deps.issubset(self._stage_done) and dep_uid not in self._stage_done:
                    already = any(
                        dep_uid in w.leases for w in self._workers.values()
                    ) or any(p.uid == dep_uid for p in self._pending)
                    if not already:
                        self._pending.append(dsi)
            self._dispatch_all_locked()
            self._check_done_locked()

    def _dispatch_all_locked(self) -> None:
        live = {
            wid: st
            for wid, st in self._workers.items()
            if not st.dead and st.runtime.alive
        }
        if self.cfg.locality_aware:
            self._dispatch_locality_locked(live)
        else:
            for wid, st in live.items():
                while len(st.leases) < self.cfg.window and self._pending:
                    self._lease_locked(wid, st, self._pending.popleft())
        if self.cfg.backup_tasks and not self._pending:
            self._issue_backups_locked()

    def _dispatch_locality_locked(
        self, live: dict[int, _WorkerState]
    ) -> None:
        """Locality-aware lease placement over the pending deque.

        First pass may *defer* a stage whose input bytes live on another
        worker that still has window slack; the second pass is purely
        work-conserving so nothing starves (demand-driven fallback).
        """
        for allow_defer in (True, False):
            progress = True
            while progress and self._pending:
                progress = False
                slack = {
                    wid
                    for wid, st in live.items()
                    if len(st.leases) < self.cfg.window
                }
                if not slack:
                    return
                for wid, st in live.items():
                    if len(st.leases) >= self.cfg.window or not self._pending:
                        continue
                    idx = select_lease(
                        self._pending,
                        wid,
                        self.directory,
                        self._input_keys,
                        self.cfg.placement,
                        workers_with_slack=slack,
                        allow_defer=allow_defer,
                    )
                    if idx is None:
                        continue
                    si = self._pending[idx]
                    del self._pending[idx]
                    self._lease_locked(wid, st, si)
                    progress = True

    def _lease_locked(
        self, wid: int, st: _WorkerState, si: StageInstance
    ) -> None:
        keys = self._input_keys(si)
        if keys:
            best = self.directory.best_worker(keys)
            if best is not None and best[1] > 0.0:
                if best[0] == wid:
                    self.placement_local += 1
                else:
                    self.placement_remote += 1
        st.leases.add(si.uid)
        self._forward_upstream_outputs(st.runtime, si)
        st.runtime.submit_stage(si)

    def _input_keys(self, si: StageInstance) -> list[RegionKey]:
        """Region keys of a stage instance's cross-stage inputs."""
        local = {oi.uid for oi in si.op_instances}
        return [
            op_key(dep_uid)
            for oi in si.op_instances
            for dep_uid in oi.deps
            if dep_uid not in local
        ]

    def _fetch_region(self, key: RegionKey) -> Any:
        """StagingAgent pull: output of a completed upstream op, or None."""
        if not (isinstance(key, tuple) and len(key) == 2 and key[0] == "op"):
            return None
        with self._lock:
            oi = self.cw.op_instances.get(key[1])
            if oi is None:
                return None
            outputs = self._stage_outputs.get(oi.stage_instance.uid)
            if not outputs:
                return None
            return outputs.get(oi.op.name)

    def _forward_upstream_outputs(self, rt: WorkerRuntime, si: StageInstance) -> None:
        """Provide cross-stage inputs (sink op outputs of upstream stages).

        Workers running a StagingAgent get the *pull* flavor: inputs not
        already staged are left for the agent to prefetch asynchronously
        (submit_stage enqueues the requests), overlapping the copy with
        whatever the lanes are executing.  Agent-less workers get the
        classic synchronous push.
        """
        lazy = getattr(rt, "agent", None) is not None
        for oi in si.op_instances:
            for dep_uid in oi.deps:
                if dep_uid not in self.cw.op_instances:
                    continue
                dep_oi = self.cw.op_instances[dep_uid]
                if dep_oi.stage_instance.uid != si.uid:
                    up_outputs = self._stage_outputs.get(
                        dep_oi.stage_instance.uid, {}
                    )
                    if dep_oi.op.name in up_outputs:
                        value = up_outputs[dep_oi.op.name]
                        if rt.mark_staged_input(dep_uid):
                            # Already staged on that worker (it ran the
                            # upstream, or its agent prefetched it): skip
                            # the copy and account the savings.
                            self.staged_bytes_avoided += sizeof(value)
                            continue
                        if lazy:
                            continue  # agent pulls via fetch_region
                        rt.provide_input(dep_uid, value)

    def _issue_backups_locked(self) -> None:
        clones_of = getattr(self, "_clones_of", None)
        if clones_of is None:
            clones_of = self._clones_of = {}
        idle = [
            st
            for st in self._workers.values()
            if not st.dead and st.runtime.alive and not st.leases
        ]
        if not idle:
            return
        outstanding: list[StageInstance] = []
        for st in self._workers.values():
            for uid in st.leases:
                if (
                    uid not in self._stage_done
                    and uid not in self._dup_issued
                    and uid not in clones_of
                ):
                    outstanding.append(self.cw.stage_instances[uid])
        for st, si in zip(idle, outstanding):
            self._dup_issued.add(si.uid)
            self.duplicated_leases += 1
            clone = self.cw._new_stage_instance(si.chunk, si.stage)  # noqa: SLF001
            # Mirror the original's cross-stage input edges so the twin
            # computes on the same upstream outputs (a bare re-instance
            # would run its source ops on the raw chunk payload).
            local = {o.uid for o in si.op_instances}
            orig_by_name = {o.op.name: o for o in si.op_instances}
            for c_oi in clone.op_instances:
                c_oi.deps |= orig_by_name[c_oi.op.name].deps - local
            clones_of[clone.uid] = si.uid
            st.leases.add(clone.uid)
            self._forward_upstream_outputs(st.runtime, clone)
            st.runtime.submit_stage(clone)

    def _check_done_locked(self) -> None:
        clones = set(self._clone_map())
        for uid in self.cw.stage_instances:
            if uid in clones:
                continue
            if uid not in self._stage_done:
                return
        self._done_event.set()

    def _monitor_loop(self) -> None:
        """Heartbeat watchdog: reap dead workers, re-lease their work."""
        while not self._stop_monitor and not self._done_event.is_set():
            time.sleep(self.cfg.poll_interval)
            now = time.monotonic()
            with self._lock:
                any_live = any(
                    not st.dead and st.runtime.alive
                    for st in self._workers.values()
                )
                for wid, st in self._workers.items():
                    if st.dead:
                        # Last-resort rejoin: every worker has been
                        # reaped yet this one's runtime reports alive.
                        # Without it a cluster whose every (healthy but
                        # slow) worker was slandered wedges with work
                        # pending and nobody to run it.  With other
                        # live workers, exclusion stands — a genuinely
                        # wedged worker must not be re-leased work; it
                        # rejoins only via a fresh heartbeat
                        # (_heartbeat), which proves progress.
                        if not any_live and st.runtime.alive:
                            st.dead = False
                            st.last_heartbeat = now
                            any_live = True
                        continue
                    inflight = bool(st.leases)
                    expired = (
                        now - st.last_heartbeat > self.cfg.heartbeat_timeout
                    )
                    if not st.runtime.alive or (inflight and expired):
                        st.dead = True
                        self.directory.drop_worker(wid)
                        for uid in st.leases:
                            if uid not in self._stage_done:
                                self.recovered_leases += 1
                                self._pending.append(
                                    self.cw.stage_instances[uid]
                                )
                        st.leases.clear()
                self._dispatch_all_locked()
                self._check_done_locked()
