"""Demand-driven Manager (paper §III-B, Fig 4) with fault tolerance.

The Manager has the overall view of the runtime: it instantiates the
abstract workflow, tracks inter-stage dependencies, and leases stage
instances to Workers demand-driven — each Worker holds at most
``window`` leases and requests more as leases complete (the paper's
*Window size*, §V-F).

Beyond the paper, the Manager provides the fault-tolerance required for
thousand-node deployments:

* **heartbeats** — a Worker that stops reporting is declared dead and
  its outstanding leases return to the queue (chunk processing is
  idempotent, so re-execution is safe);
* **straggler backup tasks** — at the tail of a run, outstanding leases
  are duplicated onto idle Workers and the first completion wins;
* **elastic membership** — Workers may register/deregister mid-run;
  the lease queue simply redistributes.

The Manager is transport-agnostic: in a single process Worker objects
are registered directly; on a cluster the same protocol runs over a
:mod:`repro.transport` MessageBus — a ``ManagerEndpoint`` serves the
lease/complete/heartbeat/region-pull RPCs and each remote worker
appears here as a ``WorkerProxy``.  With ``ManagerConfig.journal_path``
set, placement and lease state are write-ahead journaled so a restarted
Manager rehydrates instead of restarting the workflow.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .workflow import ConcreteWorkflow, StageInstance
from .worker import WorkerRuntime
from ..telemetry.metrics import MetricsRegistry
from ..telemetry.tracing import SpanContext, current_context, use_context
from ..staging import (
    DirectoryService,
    PlacementDirectory,
    PlacementPolicy,
    op_key,
    select_lease,
)
from ..staging.tiers import RegionKey, sizeof

__all__ = ["Manager", "ManagerConfig"]


@dataclass
class ManagerConfig:
    window: int = 4                  # leases in flight per worker
    heartbeat_timeout: float = 60.0  # seconds without progress => dead
    backup_tasks: bool = True       # duplicate tail leases
    poll_interval: float = 0.01
    # Cluster-level locality-aware lease placement (repro.staging): lease
    # a dependent stage instance to the worker already holding the
    # largest fraction of its input bytes, demand-driven otherwise.
    locality_aware: bool = False
    placement: PlacementPolicy = field(default_factory=PlacementPolicy)
    directory: Optional[PlacementDirectory] = None  # default: fresh one
    # Failover-surviving placement state: when set, the directory is
    # wrapped in a journaled DirectoryService at this path.  A Manager
    # constructed over a path that already holds a journal *rehydrates*:
    # holder maps, completed stages, and the pending-lease queue are
    # replayed so a restarted coordinator resumes instead of restarting.
    journal_path: Optional[str] = None
    snapshot_every: int = 512        # journal appends between checkpoints
    # Byte-keyed compaction: when set, checkpoints trigger on journal
    # *bytes* since the last snapshot (replay time is bounded by bytes
    # to parse, not append count) and snapshot_every is ignored.
    snapshot_bytes: Optional[int] = None
    # Size-tiered (incremental) checkpoints: each trigger writes only
    # the state that changed since the last checkpoint as a small delta
    # run; deltas fold into a fresh full snapshot once their byte tier
    # outgrows the base.  Keeps snapshot pauses bounded by churn, not
    # directory size — load-bearing once a serving stream keeps the
    # directory hot indefinitely.
    incremental_snapshots: bool = False
    # Predictive push of sink outputs (coordinator-bypass data plane):
    # at stage completion the placement rule predicts the next holder
    # of each sink output and the completing worker pushes the bytes
    # there before the dependent lease starts, hiding the first-touch
    # transfer.  Off by default: pull stays the baseline the benchmarks
    # compare against.
    predictive_push: bool = False
    # Data-plane flow control: cap on push bytes in flight toward any
    # single worker's ingress.  A push directive that would overflow
    # the target's cap is *deferred* (per-target queue) instead of
    # sent; the target's ``region_staged`` confirmation is the credit
    # grant that drains the queue.  With nothing in flight one push
    # always goes (a region larger than the cap degrades to
    # pull-on-lease, never a permanent stall); a dead target voids its
    # whole ledger so the cap cannot deadlock on a corpse.  None = the
    # pre-flow-control behavior (push storms queue unbounded bytes on
    # the target's ingress).  The simulator mirrors this knob as
    # ``SimConfig.push_inflight_cap_bytes``.
    push_inflight_cap_bytes: Optional[int] = None
    # Control-plane RPC timeout (seconds) the bus endpoints use for
    # manager->worker calls; the register reply hands it to workers for
    # their worker->manager calls.  Tight by design: a hung peer must
    # surface as BusTimeoutError fast, not stall the caller for the bus
    # default 30s.
    rpc_timeout: float = 10.0
    # Poison-chunk quarantine: a stage instance that fails on (or takes
    # down) this many *distinct* workers is quarantined — it and its
    # dependents become terminal failed state (surfaced through
    # ``failure_hook`` / the serving gateway) instead of being re-leased
    # forever and wedging the run.
    quarantine_after: int = 3
    # Gray-failure detection (alive-but-slow workers, distinct from
    # heartbeat death): a HealthScorer tracks each worker's EMA of
    # observed/expected stage latency (+ heartbeat jitter) and scales
    # its lease window down (capacity-weighted soft anti-affinity); a
    # worker whose score crosses ``probation_ratio`` — or that eats
    # ``probation_after_hedges`` hedges — goes on *probation*: its
    # queued leases re-queue to healthy workers and it keeps a single
    # probe lease until the score recovers, then rejoins automatically.
    # The simulator mirrors this as ``SimConfig.health_scoring``.
    health_scoring: bool = False
    health_alpha: float = 0.35            # EMA weight per ratio sample
    probation_ratio: float = 3.0          # score to enter probation
    probation_recover_ratio: float = 2.0  # score to leave probation
    probation_min_samples: int = 3        # ratio samples before benching
    probation_after_hedges: int = 2       # hedges eaten => probation
    # Percentile hedging (generalized backup tasks): a running lease
    # whose age exceeds its stage's measured latency p99 × this slack
    # is duplicated onto the healthiest worker with window slack —
    # first completion wins through the existing twin-cancel path.
    # Unlike ``backup_tasks`` (tail-of-run only), hedges fire mid-run,
    # latency-triggered against the histogram, and are health-routed.
    # None = off.  Mirrored as ``SimConfig.hedge_slack``.
    hedge_slack: Optional[float] = None
    hedge_min_samples: int = 8            # histogram count before hedging


class HealthScorer:
    """Gray-failure detector: per-worker health from latency + jitter.

    Score = EMA of the observed/expected stage-latency ratio, inflated
    by heartbeat jitter (EMA of inter-heartbeat gap over the timeout).
    1.0 = nominal; a persistently 8x-slow worker converges toward 8.
    ``weight`` maps the score to a dispatch capacity multiplier in
    (0, 1].  All calls run under the Manager lock — no lock of its own.
    """

    def __init__(self, alpha: float = 0.35) -> None:
        self.alpha = float(alpha)
        self._ratio: dict[int, float] = {}
        self._gap: dict[int, float] = {}
        self._n: dict[int, int] = {}

    def observe(self, wid: int, ratio: float) -> None:
        prev = self._ratio.get(wid, 1.0)
        self._ratio[wid] = (1.0 - self.alpha) * prev + self.alpha * ratio
        self._n[wid] = self._n.get(wid, 0) + 1

    def observe_gap(self, wid: int, gap: float) -> None:
        prev = self._gap.get(wid, 0.0)
        self._gap[wid] = (1.0 - self.alpha) * prev + self.alpha * gap

    def samples(self, wid: int) -> int:
        return self._n.get(wid, 0)

    def score(self, wid: int, heartbeat_timeout: float = 60.0) -> float:
        jitter = self._gap.get(wid, 0.0) / max(heartbeat_timeout, 1e-9)
        return self._ratio.get(wid, 1.0) * (1.0 + jitter)

    def weight(self, wid: int, heartbeat_timeout: float = 60.0) -> float:
        return min(1.0, 1.0 / max(self.score(wid, heartbeat_timeout), 1e-9))

    def reset(self, wid: int) -> None:
        """Fresh start after probation exit: a recovered worker earns
        full weight back immediately (re-entry is cheap if it relapses)."""
        self._ratio[wid] = 1.0
        self._gap[wid] = 0.0


@dataclass
class _WorkerState:
    runtime: WorkerRuntime
    leases: set[int] = field(default_factory=set)
    last_heartbeat: float = field(default_factory=time.monotonic)
    dead: bool = False
    # Gray-failure probation: the worker is alive and registered but
    # receives only a single probe lease until its health recovers.
    probation: bool = False
    probe_completions: int = 0   # completions observed while probing
    hedged_against: int = 0      # hedges issued against this worker


@dataclass
class _PushInFlight:
    """One in-flight push toward a target worker: dedup entry for the
    predictor, reserved bytes for the ingress cap, and the inbound
    hint ``forward_inputs`` hands the target's staging agent."""

    t: float              # when the push directive went out
    nbytes: int           # bytes reserved against the target's cap
    leased: bool = False  # a dependent lease already consumed the hint


class Manager:
    def __init__(
        self,
        workflow: ConcreteWorkflow,
        cfg: ManagerConfig | None = None,
        *,
        registry: MetricsRegistry | None = None,
        tracer=None,
        recorder=None,
    ):
        self.cw = workflow
        self.cfg = cfg or ManagerConfig()
        # Coordinator-side observability: every counter below is an
        # int-like cell in this registry (``manager.*``), so one
        # ``metrics.snapshot()`` covers what used to be scattered
        # attributes; ``stats()`` stays the thin compatibility view.
        self.metrics = registry or MetricsRegistry("manager")
        self.tracer = tracer          # telemetry.Tracer (optional)
        self.recorder = recorder      # telemetry.FlightRecorder (optional)
        c = lambda name: self.metrics.counter(f"manager.{name}")  # noqa: E731
        self._lock = threading.RLock()
        self._workers: dict[int, _WorkerState] = {}
        self._pending: deque[StageInstance] = deque()
        self._stage_done: set[int] = set()
        self._stage_outputs: dict[int, dict[str, Any]] = {}
        self._dup_issued: set[int] = set()
        # Trace context per queued stage instance: captured when the
        # instance enters the pending queue (the submitting thread —
        # gateway or stage-complete handler — carries the request's
        # context) and re-installed around the lease so the trace
        # follows the stage to whichever worker wins it.
        self._trace_ctx: dict[int, SpanContext] = {}
        self.recovered_leases = c("recovered_leases")
        self.duplicated_leases = c("duplicated_leases")
        # Per-lease attempt budget: primary uid -> distinct workers that
        # failed (or died) while holding it.  Crossing
        # ``cfg.quarantine_after`` quarantines the stage and its
        # dependents: terminal failed state, not an eternal re-lease.
        # Deliberately NOT journaled: after a failover the chunk re-runs,
        # re-fails, and re-quarantines — slower, never wrong.
        self._attempts: dict[int, set[int]] = {}
        self._quarantined: dict[int, str] = {}
        self.stage_failures = c("stage_failures")  # explicit worker failure reports
        self.lease_retries = c("lease_retries")    # failed leases re-queued elsewhere
        # Gray-failure resilience: per-worker health (feeds capacity-
        # weighted dispatch + probation) and per-lease dispatch times
        # (feed the stage-latency histograms and percentile hedging).
        self.health = HealthScorer(alpha=self.cfg.health_alpha)
        self._lease_t: dict[tuple[int, int], float] = {}  # (wid, uid) -> t
        self.probations = c("probations")            # workers benched as gray
        self.probation_exits = c("probation_exits")  # recovered + rejoined
        self.hedged_leases = c("hedged_leases")      # p99-triggered hedge twins
        # Called outside the lock, once per newly-quarantined primary
        # uid, as hook(uid, error) — the serving gateway maps these to
        # terminal ``failed`` request state.
        self.failure_hook: Optional[Callable[[int, str], None]] = None
        # Cluster placement metadata + locality accounting.  With a
        # journal path the directory becomes a DirectoryService whose
        # mutations are write-ahead logged; opening an existing journal
        # rehydrates holder maps and the lease ledger (failover).
        if self.cfg.journal_path is not None:
            self.directory: PlacementDirectory = DirectoryService(
                self.cfg.journal_path,
                self.cfg.directory,
                snapshot_every=self.cfg.snapshot_every,
                snapshot_bytes=self.cfg.snapshot_bytes,
                incremental=self.cfg.incremental_snapshots,
                registry=self.metrics,
            )
            for uid in self.directory.completed:
                if uid in self.cw.stage_instances:
                    self._stage_done.add(uid)
        else:
            self.directory = self.cfg.directory or PlacementDirectory()
        self.placement_local = c("placement_local")    # dependent leased where its data is
        self.placement_remote = c("placement_remote")  # dependent leased elsewhere
        self.staged_bytes_avoided = c("staged_bytes_avoided")  # inputs not re-sent
        # Coordinator data-plane accounting: region payloads this
        # coordinator relayed (fetch_region(s) serving worker pulls) vs
        # push work it only *directed* (bytes flowed worker-to-worker).
        self.relay_regions = c("relay_regions")
        self.relay_bytes = c("relay_bytes")
        self.push_directives = c("push_directives")  # delegated to a WorkerClient
        self.pushes_inline = c("pushes_inline")      # in-process targets injected directly
        # (target worker, region key) -> in-flight push ledger.  One
        # structure serves three roles: predictor dedup (a push already
        # racing toward the target is not re-sent), ingress byte
        # accounting for flow control (push_inflight_cap_bytes), and
        # the inbound hint forward_inputs consumes so the target's
        # agent defers its duplicate pull.  Entries retire on the
        # target's region_staged credit, on expiry (push evidently
        # lost), or when the target dies.
        self._push_inbound: dict[tuple[int, Any], _PushInFlight] = {}
        self._push_inflight_bytes: dict[int, int] = {}  # twid -> reserved
        # Flow control: directives queued behind a full ingress cap,
        # drained oldest-first as region_staged credits return.
        self._push_deferred: dict[int, deque] = {}
        self._push_deferred_keys: set[tuple[int, Any]] = set()
        self.pushes_deferred = c("pushes_deferred")  # directives that waited for credit
        self.pushes_dropped = c("pushes_dropped")    # deferred directives voided (death)
        self.push_inflight_peak: dict[int, int] = {}  # max reserved/target
        self._done_event = threading.Event()
        self._monitor: Optional[threading.Thread] = None
        self._stop_monitor = False
        # Serving front end (repro.serving): while a stream is open the
        # workflow is never "done" — new stage instances keep arriving
        # via submit_instances.  completion_hook (called outside the
        # lock, once per completed primary stage) lets a gateway map
        # completions back to requests.
        self._streaming = False
        self.completion_hook: Optional[Callable[[int], None]] = None
        # Count of deadline-carrying instances in the pending queue:
        # keeps the EDF insert on the serving path only (batch pushes
        # stay O(1) appends).
        self._pending_deadlines = 0

    # -- membership -------------------------------------------------------

    def register_worker(
        self,
        runtime: WorkerRuntime,
        address: Any = None,
        rack: Any = None,
    ) -> None:
        runtime.on_stage_complete = self._make_completion_cb(runtime.worker_id)
        runtime.on_stage_failed = self._make_failure_cb(runtime.worker_id)
        runtime.on_heartbeat = self._heartbeat  # per-op liveness pings
        # Region pull path: the StagingAgent prefetches completed
        # upstream outputs, and lanes re-pull inputs evicted under soft
        # tier budgets (worker._gather_inputs fallback).  fetch_regions
        # is the batched flavor: one round-trip per coalesced key batch.
        runtime.fetch_region = self._fetch_region
        runtime.fetch_regions = self._fetch_regions
        # Keep the directory honest: a region falling off the worker's
        # bottom tier is no longer a replica there (lease placement and
        # the eviction preference below both read this map).
        wid = runtime.worker_id
        runtime.store.on_drop = (
            lambda key, _wid=wid: self.directory.evict(_wid, key)
        )
        # Replication-aware eviction: under budget pressure the worker's
        # host tier sheds regions the directory shows replicated on
        # another worker before sole copies (policy knob).
        if self.cfg.placement.replication_aware_eviction:
            try:
                host = runtime.store.tier("host")
            except KeyError:
                host = None
            if host is not None:
                host.replicated = (
                    lambda key, _wid=wid: self.directory.replicated_elsewhere(
                        _wid, key
                    )
                )
        newly_q: list[int] = []
        with self._lock:
            # A relaunched worker re-registering its id must not orphan
            # the old incarnation's in-flight leases: recover them first
            # (chunk processing is idempotent), and drop the dead
            # incarnation's replicas from the directory.  Each lost
            # lease charges the dead incarnation against the chunk's
            # attempt budget — a chunk that keeps taking workers down
            # quarantines instead of cycling through the fleet.
            old = self._workers.get(wid)
            if old is not None:
                # Snapshot: crossing the attempt budget cancels leases
                # (mutates this set mid-iteration otherwise).
                for uid in list(old.leases):
                    if uid not in self._stage_done and self._charge_attempt_locked(
                        wid, uid, "worker lost mid-lease", newly_q
                    ):
                        self.recovered_leases += 1
                        self._push_pending_locked(self.cw.stage_instances[uid])
                self.directory.drop_worker(wid)
                # Pushes racing toward the dead incarnation are void:
                # release their reserved ingress bytes.
                self._abort_push_target_locked(wid)
            self._workers[wid] = _WorkerState(runtime=runtime)
            if address is not None:
                # Data-plane address: lets sibling workers dial this one
                # for region bytes instead of relaying through here.
                self.directory.set_address(wid, address)
            if rack is not None:
                # Topology identity: placement scoring can prefer
                # same-rack replicas (PlacementPolicy.rack_affinity).
                self.directory.set_rack(wid, rack)
            self._dispatch_all_locked()
            self._check_done_locked()
        self._fire_failure_hooks(newly_q)

    def _heartbeat(self, worker_id: int) -> None:
        with self._lock:
            st = self._workers.get(worker_id)
            if st is not None:
                now = time.monotonic()
                if self.cfg.health_scoring and not st.dead:
                    # Heartbeat jitter is the second gray-failure signal
                    # (a worker whose pings stretch toward the timeout
                    # is degrading even if nothing has completed yet).
                    self.health.observe_gap(worker_id, now - st.last_heartbeat)
                st.last_heartbeat = now
                if st.dead and st.runtime.alive:
                    # A fresh heartbeat after a reap proves the "dead"
                    # worker was merely slow (one op outlasted the
                    # window): rejoin it.  Its leases were already
                    # recovered; chunk processing is idempotent.  Under
                    # health scoring the slander itself is evidence of
                    # slowness, so it rejoins *as probing* — one probe
                    # lease until the score proves it healthy — never
                    # straight back to full weight.
                    st.dead = False
                    if self.cfg.health_scoring and not st.probation:
                        self._enter_probation_locked(
                            worker_id, st, self.health.score(
                                worker_id, self.cfg.heartbeat_timeout
                            ), "slander rejoin",
                        )
                    self._dispatch_all_locked()

    def deregister_worker(self, worker_id: int) -> int:
        """Elastic scale-down / drain: atomically release the worker's
        in-flight push reservations AND re-queue its outstanding leases.

        Everything happens under one lock hold so no dispatch can
        observe the half-drained state (leases gone but ingress credit
        still reserved, or vice versa).  In-flight ops on the draining
        runtime are cancelled best-effort; a completion that races past
        the cancel is dropped by ``_on_stage_complete`` (the worker is
        no longer registered), so the re-queued twin is authoritative.
        Returns the number of leases returned to the queue.
        """
        with self._lock:
            st = self._workers.pop(worker_id, None)
            if st is None:
                return 0
            requeued = 0
            for uid in sorted(st.leases):
                self._lease_t.pop((worker_id, uid), None)
                if uid not in self._stage_done:
                    try:
                        st.runtime.cancel_stage(uid)
                    except Exception:
                        pass  # runtime may already be gone
                    self.recovered_leases += 1
                    requeued += 1
                    self._push_pending_locked(self.cw.stage_instances[uid])
            st.leases.clear()
            self.directory.drop_worker(worker_id)
            # Pushes racing toward the drained worker are void: release
            # their reserved ingress bytes and drop the deferred queue,
            # else the credit leaks until the 10s expiry sweep (or
            # forever, for deferred entries that never get admitted).
            self._abort_push_target_locked(worker_id)
            self._dispatch_all_locked()
            return requeued

    # ``drain`` is the serving-facing name for graceful scale-down; it
    # is the same atomic operation as a deregistration.
    drain_worker = deregister_worker

    def _push_pending_locked(self, si: StageInstance) -> None:
        # EDF tier: deadline-carrying instances (serving requests) sort
        # earliest-first at the head of the queue, ahead of deadline-free
        # batch work.  The pending invariant is [deadlines ascending] +
        # [batch FIFO]; batch pushes keep their O(1) append.
        ctx = current_context()
        if ctx is not None and ctx.sampled:
            # First queueing wins: a recovery re-queue from the monitor
            # thread (no ambient context) must not clobber the request's
            # context, and neither must an unrelated caller's.
            self._trace_ctx.setdefault(si.uid, ctx)
        if getattr(si, "deadline", None) is None:
            self._pending.append(si)
        else:
            i = 0
            for p in self._pending:
                d = getattr(p, "deadline", None)
                if d is None or d > si.deadline:
                    break
                i += 1
            self._pending.insert(i, si)
            self._pending_deadlines += 1
        svc = self._journal_svc()
        if svc is not None:
            svc.note_pending(si.uid)

    def _pop_pending_locked(self, idx: int = 0) -> StageInstance:
        si = self._pending[idx] if idx else self._pending[0]
        if idx:
            del self._pending[idx]
        else:
            self._pending.popleft()
        if getattr(si, "deadline", None) is not None:
            self._pending_deadlines -= 1
        return si

    # -- execution -----------------------------------------------------------

    def run(self, timeout: float = 120.0) -> bool:
        """Lease everything and block until the workflow completes."""
        with self._lock:
            # One membership set up front: at fig14 scale (~37k ready
            # instances) rebuilding it per stage would be O(P^2).
            queued = {p.uid for p in self._pending}
            queued.update(
                uid for w in self._workers.values() for uid in w.leases
            )
            for si in self.cw.ready_stage_instances(self._stage_done):
                if si.uid not in queued:
                    queued.add(si.uid)
                    self._push_pending_locked(si)
            self._dispatch_all_locked()
        self._stop_monitor = False
        self._monitor = threading.Thread(target=self._monitor_loop, daemon=True)
        self._monitor.start()
        ok = self._done_event.wait(timeout=timeout)
        self._stop_monitor = True
        self._monitor.join(timeout=2.0)
        return ok

    # -- streaming (serving front end) ---------------------------------------

    def open_stream(self) -> None:
        """Switch to continuous-ingestion mode: the workflow is no
        longer a fixed bag of tasks, so completion of everything
        currently known must NOT fire the done event — more requests
        may arrive.  Starts the heartbeat monitor so elastic membership
        works without a blocking :meth:`run` call."""
        with self._lock:
            self._streaming = True
            self._done_event.clear()
        if self._monitor is None or not self._monitor.is_alive():
            self._stop_monitor = False
            self._monitor = threading.Thread(
                target=self._monitor_loop, daemon=True
            )
            self._monitor.start()

    def close_stream(self, timeout: float = 120.0) -> bool:
        """End continuous ingestion: wait for everything already
        admitted to finish, then stop the monitor.  Returns False on
        timeout."""
        with self._lock:
            self._streaming = False
            self._dispatch_all_locked()
            self._check_done_locked()
        ok = self._done_event.wait(timeout=timeout)
        self._stop_monitor = True
        if self._monitor is not None:
            self._monitor.join(timeout=2.0)
        return ok

    def submit_instances(self, sis: list[StageInstance]) -> None:
        """Streamed submission: queue ready instances appended to the
        live workflow (``ConcreteWorkflow.instantiate``) and dispatch.
        Instances whose deps are not yet done unlock through the normal
        ``_on_stage_complete`` path."""
        with self._lock:
            queued = {p.uid for p in self._pending}
            queued.update(
                uid for w in self._workers.values() for uid in w.leases
            )
            for si in sis:
                if (
                    si.uid in self._stage_done
                    or si.uid in self._quarantined
                    or si.uid in queued
                ):
                    continue
                if si.deps.issubset(self._stage_done):
                    queued.add(si.uid)
                    self._push_pending_locked(si)
            self._dispatch_all_locked()

    def progress(self) -> tuple[int, int]:
        with self._lock:
            total = sum(
                1 for uid in self.cw.stage_instances if uid not in self._clone_map()
            )
            return len(self._stage_done - set(self._clone_map())), total

    def stage_outputs(self, uid: int) -> dict[str, Any]:
        with self._lock:
            return self._stage_outputs.get(uid, {})

    # -- internals ---------------------------------------------------------------

    def _clone_map(self) -> dict[int, int]:
        return getattr(self, "_clones_of", {})

    def _make_completion_cb(self, worker_id: int):
        def cb(
            si: StageInstance,
            outputs: dict[str, Any],
            exec_s: Optional[float] = None,
        ) -> None:
            self._on_stage_complete(worker_id, si, outputs, exec_s)

        return cb

    def _on_stage_complete(
        self,
        worker_id: int,
        si: StageInstance,
        outputs: dict[str, Any],
        exec_s: Optional[float] = None,
    ) -> None:
        completed: Optional[int] = None
        with self._lock:
            st = self._workers.get(worker_id)
            if st is None:
                # Completion racing past a drain/deregister: the lease
                # was already re-queued and the worker's store is gone.
                # Recording its outputs would point dependents at a
                # holder nobody can dial; the re-leased twin wins.
                return
            now = time.monotonic()
            st.last_heartbeat = now
            clones_of = self._clone_map()
            primary_uid = clones_of.get(si.uid, si.uid)
            lease_t0 = self._lease_t.pop((worker_id, si.uid), None)
            if primary_uid in self._stage_done:
                return  # a backup twin already completed this lease
            if primary_uid in self._quarantined:
                # Completion racing past a quarantine decision: the
                # stage is already terminally accounted as failed —
                # recording it done too would double-count the tile.
                return
            # Gray-failure signal: this worker's observed stage latency
            # against the cross-worker distribution.  The histogram is
            # per stage name so heterogeneous stages don't pollute each
            # other's p99 (hedging) or median (health ratio).
            if lease_t0 is not None:
                elapsed = now - lease_t0
                hist = self._stage_hist(si.stage.name)
                # Health prefers the worker-reported execution seconds:
                # lease latency includes queueing, so a probe lease
                # (empty queue) judged against queue-inflated medians
                # exits probation on a coin flip.  Fall back to lease
                # latency for runtimes that don't report exec time.
                if exec_s is not None:
                    eh = self._exec_hist(si.stage.name)
                    expected = eh.percentile(0.5)
                    sample = exec_s
                else:
                    expected = hist.percentile(0.5)
                    sample = elapsed
                # Suspects don't write the baselines: one benched
                # worker's 8x latencies would drag the stage p99 up to
                # *its* speed, raising the hedge trigger exactly when
                # hedges are most needed (observed: a stuck probe aged
                # 5s before hedging because p99 had absorbed the
                # straggler's own queue-inflated samples).
                if not st.probation:
                    hist.observe(elapsed)
                    if exec_s is not None:
                        self._exec_hist(si.stage.name).observe(exec_s)
                if (
                    self.cfg.health_scoring
                    and expected is not None
                    and expected > 0.0
                ):
                    self.health.observe(worker_id, sample / expected)
                    self._update_probation_locked(worker_id, st)
            self._stage_done.add(primary_uid)
            if si.uid != primary_uid:
                self._stage_done.add(si.uid)
            self._trace_ctx.pop(primary_uid, None)
            self._trace_ctx.pop(si.uid, None)
            self._stage_outputs[primary_uid] = outputs
            for w_wid, wst in self._workers.items():
                wst.leases.discard(si.uid)
                wst.leases.discard(primary_uid)
                self._lease_t.pop((w_wid, si.uid), None)
                self._lease_t.pop((w_wid, primary_uid), None)
                # Cancel twins on other workers.
                for c_uid, p_uid in clones_of.items():
                    if p_uid == primary_uid and c_uid in wst.leases:
                        wst.runtime.cancel_stage(c_uid)
                        wst.leases.discard(c_uid)
                        self._lease_t.pop((w_wid, c_uid), None)
            primary = self.cw.stage_instances[primary_uid]
            # The completing worker now holds this stage's sink outputs:
            # record placements so dispatch can route dependents to it.
            sinks = set(primary.stage.sinks())
            for oi in primary.op_instances:
                if oi.op.name in sinks and outputs.get(oi.op.name) is not None:
                    if si.uid != primary_uid and st is not None:
                        # A backup twin finished: its store holds the
                        # outputs under the clone's op uids.  Alias them
                        # under the primary keys (same objects, no copy)
                        # so the placement below is actually serviceable.
                        st.runtime.provide_input(oi.uid, outputs[oi.op.name])
                    self.directory.record(
                        worker_id, op_key(oi.uid), sizeof(outputs[oi.op.name])
                    )
            # Journal the completion only AFTER the sink placements: a
            # crash in between must rehydrate the stage as *incomplete*
            # (idempotent re-run) rather than as done-with-no-holders,
            # which would wedge push-mode dependents.
            svc = self._journal_svc()
            if svc is not None:
                svc.note_complete(primary_uid)
            # Unlock downstream stage instances and forward their inputs.
            for dep_uid in primary.dependents:
                dsi = self.cw.stage_instances[dep_uid]
                if dsi.deps.issubset(self._stage_done) and dep_uid not in self._stage_done:
                    already = any(
                        dep_uid in w.leases for w in self._workers.values()
                    ) or any(p.uid == dep_uid for p in self._pending)
                    if not already:
                        self._push_pending_locked(dsi)
            # Predictive push: BEFORE the dispatch below leases the
            # newly-ready dependents, predict where they will land and
            # direct the holders (push_request notify — the completing
            # worker is already a directory holder of its sinks) to push
            # the missing inputs there.  The notifies are in flight
            # while dispatch still runs, so the bytes race *ahead of*
            # the lease instead of trailing its first touch.
            if self.cfg.predictive_push:
                self._predict_pushes_locked(worker_id, primary, outputs)
            self._dispatch_all_locked()
            self._check_done_locked()
            completed = primary_uid
        # Outside the lock: the serving gateway's hook may re-enter the
        # Manager (submit more instances when a request finishes).
        if completed is not None and self.completion_hook is not None:
            self.completion_hook(completed)

    # -- failure handling / poison-chunk quarantine --------------------------

    def _make_failure_cb(self, worker_id: int):
        def cb(si: Any, error: str) -> None:
            uid = si if isinstance(si, int) else si.uid
            self.stage_failed(worker_id, uid, str(error))

        return cb

    def stage_failed(self, worker_id: int, uid: int, error: str) -> None:
        """A worker reports a lease whose op raised (the worker itself
        is healthy and keeps serving).  The lease is charged against the
        chunk's attempt budget and re-queued elsewhere; a chunk that
        fails on ``cfg.quarantine_after`` distinct workers is poison —
        quarantined together with its dependents instead of being
        re-leased forever.  Idempotent per (stage, worker): retried
        ``stage_failed`` RPCs re-add the same worker to the same set."""
        newly_q: list[int] = []
        with self._lock:
            st = self._workers.get(worker_id)
            if st is not None:
                st.last_heartbeat = time.monotonic()
                st.leases.discard(uid)
                self._lease_t.pop((worker_id, uid), None)
            pu = self._clone_map().get(uid, uid)
            if pu in self._stage_done or pu in self._quarantined:
                return  # a twin completed, or already terminal
            self.stage_failures += 1
            if self._charge_attempt_locked(worker_id, uid, error, newly_q):
                # Not (yet) poison: retry elsewhere — unless a backup
                # twin of the same primary is still running or queued.
                clone_uids = {
                    c for c, p in self._clone_map().items() if p == pu
                }
                active = {pu} | clone_uids
                already = any(
                    active & w.leases for w in self._workers.values()
                ) or any(p.uid in active for p in self._pending)
                if not already:
                    self.lease_retries += 1
                    self._push_pending_locked(self.cw.stage_instances[pu])
            self._dispatch_all_locked()
            self._check_done_locked()
        self._fire_failure_hooks(newly_q)

    def _charge_attempt_locked(
        self, worker_id: int, uid: int, error: str, quarantined_out: list[int]
    ) -> bool:
        """Charge one failed attempt of ``uid`` to ``worker_id``.
        Returns True when the caller should re-queue the lease; False
        when the stage is already terminal or just crossed the budget
        (newly-quarantined primary uids are appended to
        ``quarantined_out`` for hook delivery outside the lock)."""
        pu = self._clone_map().get(uid, uid)
        if pu in self._stage_done or pu in self._quarantined:
            return False
        tried = self._attempts.setdefault(pu, set())
        tried.add(worker_id)
        # Terminal when the distinct-worker budget fills, OR when every
        # live worker has already tried the stage — re-leasing can only
        # cycle through workers that already failed it, so the budget
        # could never fill (the effective budget on a small cluster is
        # min(quarantine_after, live width)).  An empty live set (total
        # outage) does not quarantine: workers may come back.
        live = {
            w
            for w, ws in self._workers.items()
            if not ws.dead and ws.runtime.alive
        }
        if len(tried) >= max(self.cfg.quarantine_after, 1) or (
            live and live <= tried
        ):
            quarantined_out.extend(self._quarantine_locked(pu, error))
            return False
        return True

    def _quarantine_locked(self, uid: int, error: str) -> list[int]:
        """Quarantine ``uid`` and cascade over its dependents (a stage
        downstream of a quarantined input can never run).  Pending
        entries are removed, live leases (and backup twins) cancelled.
        Returns the newly-quarantined primary uids."""
        newly: list[int] = []
        stack: list[tuple[int, str]] = [(uid, error)]
        while stack:
            u, err = stack.pop()
            pu = self._clone_map().get(u, u)
            if pu in self._quarantined or pu in self._stage_done:
                continue
            self._quarantined[pu] = err
            self._trace_ctx.pop(pu, None)
            newly.append(pu)
            for i, p in enumerate(self._pending):
                if self._clone_map().get(p.uid, p.uid) == pu:
                    self._pop_pending_locked(i)
                    break
            clone_uids = {c for c, p in self._clone_map().items() if p == pu}
            active = {pu} | clone_uids
            for wst in self._workers.values():
                for cu in active & wst.leases:
                    try:
                        wst.runtime.cancel_stage(cu)
                    except Exception:
                        pass  # runtime may already be gone
                    wst.leases.discard(cu)
            si = self.cw.stage_instances.get(pu)
            if si is not None:
                stack.extend(
                    (d, f"upstream stage {pu} quarantined: {err}")
                    for d in si.dependents
                )
        return newly

    def _fire_failure_hooks(self, uids: list[int]) -> None:
        if not uids:
            return
        if self.recorder is not None:
            # A quarantine is a postmortem moment: freeze the recent
            # span/event ring before the hooks mutate downstream state.
            self.recorder.dump(
                "quarantine",
                detail={
                    "uids": list(uids),
                    "errors": {
                        u: self._quarantined.get(u, "quarantined")
                        for u in uids
                    },
                },
            )
        hook = self.failure_hook
        if hook is None:
            return
        for uid in uids:
            try:
                hook(uid, self._quarantined.get(uid, "quarantined"))
            except Exception:
                pass  # surfacing is best-effort; accounting already done

    def quarantined(self) -> dict[int, str]:
        """Snapshot of quarantined primary stage uids -> error."""
        with self._lock:
            return dict(self._quarantined)

    def stats(self) -> dict[str, Any]:
        """Wire-safe coordinator stats: a thin view over the
        ``manager.*`` registry cells plus live queue/membership gauges
        (served over the bus by the ``get_stats`` RPC)."""
        with self._lock:
            out: dict[str, Any] = {
                "recovered_leases": int(self.recovered_leases),
                "duplicated_leases": int(self.duplicated_leases),
                "stage_failures": int(self.stage_failures),
                "lease_retries": int(self.lease_retries),
                "placement_local": int(self.placement_local),
                "placement_remote": int(self.placement_remote),
                "staged_bytes_avoided": int(self.staged_bytes_avoided),
                "relay_regions": int(self.relay_regions),
                "relay_bytes": int(self.relay_bytes),
                "push_directives": int(self.push_directives),
                "pushes_inline": int(self.pushes_inline),
                "pushes_deferred": int(self.pushes_deferred),
                "pushes_dropped": int(self.pushes_dropped),
                "push_inflight_peak": dict(self.push_inflight_peak),
                "probations": int(self.probations),
                "probation_exits": int(self.probation_exits),
                "hedged_leases": int(self.hedged_leases),
                "workers_probing": sum(
                    1 for ws in self._workers.values() if ws.probation
                ),
                "workers": len(self._workers),
                "pending": len(self._pending),
                "stages_done": len(self._stage_done),
                "quarantined": len(self._quarantined),
            }
        svc = self._journal_svc()
        if svc is not None:
            out["directory"] = svc.stats()
        if self.tracer is not None:
            out["tracing"] = self.tracer.stats()
        return out

    def _dispatch_all_locked(self) -> None:
        live = {
            wid: st
            for wid, st in self._workers.items()
            if not st.dead and st.runtime.alive
        }
        if self.cfg.locality_aware:
            self._dispatch_locality_locked(live)
        else:
            for wid, st in live.items():
                while len(st.leases) < self._window_for_locked(wid, st) and self._pending:
                    idx = next(
                        (
                            i
                            for i, p in enumerate(self._pending)
                            if not self._avoid_lease_locked(wid, p.uid, live)
                        ),
                        None,
                    )
                    if idx is None:
                        break
                    self._lease_locked(wid, st, self._pop_pending_locked(idx))
        if self.cfg.backup_tasks and not self._pending:
            self._issue_backups_locked()

    def _dispatch_locality_locked(
        self, live: dict[int, _WorkerState]
    ) -> None:
        """Locality-aware lease placement over the pending deque.

        First pass may *defer* a stage whose input bytes live on another
        worker that still has window slack; the second pass is purely
        work-conserving so nothing starves (demand-driven fallback).
        """
        for allow_defer in (True, False):
            progress = True
            while progress and self._pending:
                progress = False
                slack = {
                    wid
                    for wid, st in live.items()
                    if len(st.leases) < self._window_for_locked(wid, st)
                }
                if not slack:
                    return
                for wid, st in live.items():
                    if (
                        len(st.leases) >= self._window_for_locked(wid, st)
                        or not self._pending
                    ):
                        continue
                    idx = select_lease(
                        self._pending,
                        wid,
                        self.directory,
                        self._input_keys,
                        self.cfg.placement,
                        workers_with_slack=slack,
                        allow_defer=allow_defer,
                    )
                    if idx is None:
                        continue
                    if self._avoid_lease_locked(
                        wid, self._pending[idx].uid, live
                    ):
                        continue  # an untried worker must take this one
                    si = self._pop_pending_locked(idx)
                    self._lease_locked(wid, st, si)
                    progress = True

    def _avoid_lease_locked(
        self, wid: int, uid: int, live: dict[int, _WorkerState]
    ) -> bool:
        """Soft anti-affinity for charged retries: never re-lease a
        stage to a worker that already failed it while an untried live
        worker exists.  Without this the distinct-worker quarantine
        budget can starve — a poison chunk ping-pongs on whichever
        worker frees a slot first and is re-leased forever.  When every
        live worker has tried the stage the check stands down (work
        conservation beats affinity; the budget decides from there)."""
        if not self._attempts:
            return False
        tried = self._attempts.get(self._clone_map().get(uid, uid))
        if not tried or wid not in tried:
            return False
        return any(w not in tried for w in live)

    def _window_for_locked(self, wid: int, st: _WorkerState) -> int:
        """Effective lease window for a worker: the configured window
        scaled by the health weight (capacity-weighted soft
        anti-affinity — a 4x-slow worker at window 4 gets 1 lease), and
        a single probe lease while on probation so recovery stays
        observable at bounded cost.  Probes are granted only from
        *surplus* backlog: when healthy workers have free slots for
        everything pending, handing a stage to the suspect converts a
        fast completion into a slow one — worst at the tail, where one
        probe lease can hold the whole run hostage until a hedge fires."""
        if not self.cfg.health_scoring:
            return self.cfg.window
        if st.probation:
            healthy_slack = sum(
                max(self.cfg.window - len(ws.leases), 0)
                for w2, ws in self._workers.items()
                if w2 != wid
                and not ws.dead
                and ws.runtime.alive
                and not ws.probation
            )
            return 1 if len(self._pending) > healthy_slack else 0
        w = self.health.weight(wid, self.cfg.heartbeat_timeout)
        return max(1, int(self.cfg.window * w + 1e-9))

    def _lease_locked(
        self, wid: int, st: _WorkerState, si: StageInstance
    ) -> None:
        self._lease_t[(wid, si.uid)] = time.monotonic()
        keys = self._input_keys(si)
        if keys:
            best = self.directory.best_worker(keys)
            if best is not None and best[1] > 0.0:
                if best[0] == wid:
                    self.placement_local += 1
                else:
                    self.placement_remote += 1
        st.leases.add(si.uid)
        svc = self._journal_svc()
        if svc is not None:
            svc.note_lease(si.uid, wid)
        ctx = self._trace_ctx.get(si.uid)
        if ctx is not None:
            # Re-install the request's context around the dispatch: the
            # submit_stage call (direct or over a TracingBus) carries it
            # to the worker, and the lease itself becomes a span.
            with use_context(ctx):
                if self.tracer is not None:
                    with self.tracer.span(
                        "stage:lease",
                        cat="sched",
                        args={"uid": si.uid, "worker": wid},
                    ):
                        self._forward_upstream_outputs(st.runtime, si)
                        st.runtime.submit_stage(si)
                else:
                    self._forward_upstream_outputs(st.runtime, si)
                    st.runtime.submit_stage(si)
        else:
            self._forward_upstream_outputs(st.runtime, si)
            st.runtime.submit_stage(si)

    def _journal_svc(self) -> Optional[DirectoryService]:
        d = self.directory
        return d if isinstance(d, DirectoryService) else None

    def _input_keys(self, si: StageInstance) -> list[RegionKey]:
        """Region keys of a stage instance's cross-stage inputs."""
        local = {oi.uid for oi in si.op_instances}
        return [
            op_key(dep_uid)
            for oi in si.op_instances
            for dep_uid in oi.deps
            if dep_uid not in local
        ]

    # -- coordinator-bypass data plane --------------------------------------

    def resolve_regions(
        self, keys: list, exclude: Optional[int] = None
    ) -> list:
        """Directory lookup for worker-to-worker transfer: for each key
        the ``(worker_id, bus_address)`` of a live holder (largest
        replica first), or None when only the Manager route can serve
        it.  This is the whole control-plane cost of a direct transfer:
        metadata out, bytes never through here."""
        out: list = []
        with self._lock:
            for key in keys:
                best = None
                holders = self.directory.holders(key)
                for wid in sorted(holders, key=lambda w: -holders[w]):
                    if wid == exclude:
                        continue
                    st = self._workers.get(wid)
                    if st is None or st.dead or not st.runtime.alive:
                        continue
                    addr = self.directory.address_of(wid)
                    if addr is None:
                        continue
                    best = (wid, addr)
                    break
                out.append(best)
        return out

    def region_staged(self, worker_id: int, key: RegionKey, nbytes: int) -> None:
        """A pushed replica landed on ``worker_id``: record it (journaled
        when a DirectoryService backs the directory) so dependents — and
        a restarted coordinator — can route to the new holder.

        This confirmation is also the flow-control **credit grant**:
        the landed bytes release their ingress-cap reservation and the
        target's deferred-push queue drains as far as the freed credit
        allows.

        A confirmation racing in after the target drained (elastic
        scale-down) must NOT resurrect the dead worker as a directory
        holder — the bytes landed in a store nobody can dial anymore.
        The reservation is still released either way so the ingress
        ledger cannot leak.
        """
        with self._lock:
            st = self._workers.get(worker_id)
            live = st is not None and not st.dead and st.runtime.alive
            if live:
                self.directory.record(worker_id, key, int(nbytes))
            self._release_push_locked((worker_id, key))
            if live:
                self._drain_push_deferred_locked(worker_id)

    def push_region_toward(self, key: RegionKey, target_wid: int) -> bool:
        """Explicitly route one region push toward ``target_wid``
        through the flow-controlled push path (the same admit / defer /
        credit accounting the predictive pusher uses).  Returns False
        when the push cannot be routed at all (unknown or dead target,
        no live holder with a data plane)."""
        with self._lock:
            now = time.monotonic()
            self._expire_pushes_locked(now)
            tst = self._workers.get(target_wid)
            if tst is None or tst.dead or not tst.runtime.alive:
                return False
            return self._push_one_locked(None, target_wid, tst, key, now)

    def _predict_pushes_locked(
        self, worker_id: int, primary: StageInstance, outputs: dict[str, Any]
    ) -> None:
        """Predictive push for ``primary``'s newly-ready dependents.

        Prediction = the same rule the dispatch below uses (pending-
        queue affinity under locality-aware placement, window-slack FIFO
        otherwise), run virtually.  EVERY input the predicted worker is
        missing gets pushed ahead of the lease: bus holders get a
        ``push_request`` notify (the completing worker is already a
        directory holder of its just-recorded sinks, so one mechanism
        covers both fresh and older regions), in-process targets are
        injected directly (zero copy).  Bytes never touch the Manager.
        """
        now = time.monotonic()
        self._expire_pushes_locked(now)
        sink_uids = {
            oi.uid
            for oi in primary.op_instances
            if oi.op.name in primary.stage.sinks()
        }
        ready: list[int] = []
        upcoming: list[int] = []
        for uid in primary.dependents:
            if uid in self._stage_done:
                continue
            dsi = self.cw.stage_instances[uid]
            (ready if dsi.deps.issubset(self._stage_done) else upcoming).append(
                uid
            )
        targets = self._predict_assignment_locked(ready)
        for uid in upcoming:
            # A dependent still waiting on other upstreams: its lease is
            # not imminent, but THIS completion's sinks can start moving
            # toward wherever its inputs are accumulating — counting
            # both recorded holders AND in-flight upstream leases (that
            # output will materialize on the leased worker).  By the
            # time the last upstream finishes, the fan-in is already
            # staged and the transfer rode under its compute.
            twid = self._predict_upcoming_locked(uid)
            if twid is not None:
                targets[uid] = twid
        pushed: set[tuple[int, RegionKey]] = set()
        for dep_uid in ready + upcoming:
            twid = targets.get(dep_uid)
            if twid is None:
                continue
            tst = self._workers.get(twid)
            if tst is None or tst.dead:
                continue
            dsi = self.cw.stage_instances[dep_uid]
            cross = self._cross_dep_uids(dsi)
            if dep_uid in upcoming:
                # Only this completion's own sinks are pushed early;
                # other inputs move when their producers complete.
                cross &= sink_uids
            for dep in sorted(cross):
                key = op_key(dep)
                if (
                    (twid, key) in pushed
                    or (twid, key) in self._push_inbound
                    or (twid, key) in self._push_deferred_keys
                ):
                    continue  # this push is already in flight / queued
                if self.directory.holders(key).get(twid):
                    continue  # the predicted worker already holds it
                if self._push_one_locked(worker_id, twid, tst, key, now):
                    pushed.add((twid, key))

    def _cross_dep_uids(self, si: StageInstance) -> set[int]:
        local = {oi.uid for oi in si.op_instances}
        return {
            u for oi in si.op_instances for u in oi.deps if u not in local
        }

    def _predict_upcoming_locked(self, dep_uid: int) -> Optional[int]:
        """Predicted worker for a dependent whose upstreams are still
        running: one vote per input already held (directory) plus one
        per input whose producer stage is currently leased there."""
        dsi = self.cw.stage_instances[dep_uid]
        lease_of = {
            uid: wid
            for wid, st in self._workers.items()
            if not st.dead
            for uid in st.leases
        }
        votes: dict[int, int] = {}
        for dep in self._cross_dep_uids(dsi):
            for wid in self.directory.holders(op_key(dep)):
                votes[wid] = votes.get(wid, 0) + 1
            dep_oi = self.cw.op_instances.get(dep)
            if dep_oi is not None:
                # Still-running producer: its output will materialize on
                # the worker holding its lease (leases are dropped at
                # completion, so this never double-counts a holder).
                wid = lease_of.get(dep_oi.stage_instance.uid)
                if wid is not None:
                    votes[wid] = votes.get(wid, 0) + 1
        live = {
            wid
            for wid, st in self._workers.items()
            if not st.dead and st.runtime.alive
        }
        votes = {w: v for w, v in votes.items() if w in live}
        if not votes:
            return None
        return max(votes, key=lambda w: (votes[w], -w))

    def _push_one_locked(
        self,
        worker_id: Optional[int],
        twid: int,
        tst: "_WorkerState",
        key: RegionKey,
        now: float,
    ) -> bool:
        """Route one region push toward predicted worker ``twid``,
        subject to the per-target in-flight byte cap: a push that would
        overflow the target's ingress credit is queued on its deferred
        list and re-issued when ``region_staged`` credits return."""
        if (
            (twid, key) in self._push_inbound
            or (twid, key) in self._push_deferred_keys
        ):
            # Already racing / queued toward this target: a duplicate
            # request (caller retry) must not double-reserve its bytes.
            return True
        trt = tst.runtime
        if callable(getattr(trt, "ingest_push", None)):
            # In-process target: the Manager holds the output copy —
            # the "push" is a reference hand-over, done right here
            # (zero copy, no ingress queue, so no flow control either).
            dep = key[1] if isinstance(key, tuple) and len(key) == 2 else None
            dep_oi = self.cw.op_instances.get(dep)
            if dep_oi is None:
                return False
            up = self._stage_outputs.get(dep_oi.stage_instance.uid, {})
            value = up.get(dep_oi.op.name)
            if value is None:
                return False
            trt.ingest_push(key, value)
            self.directory.record(twid, key, sizeof(value))
            self.pushes_inline += 1
            return True
        if self.directory.address_of(twid) is None:
            return False  # target has no data plane: pull remains
        est = max(self.directory.holders(key).values(), default=0)
        if not self._push_admit_locked(twid, est):
            self._push_deferred.setdefault(twid, deque()).append(
                (worker_id, key)
            )
            self._push_deferred_keys.add((twid, key))
            self.pushes_deferred += 1
            return True  # queued: the push is owed, not abandoned
        return self._issue_push_locked(worker_id, twid, tst, key, now, est)

    def _issue_push_locked(
        self,
        worker_id: Optional[int],
        twid: int,
        tst: "_WorkerState",
        key: RegionKey,
        now: float,
        est: int,
    ) -> bool:
        """Send one admitted push directive and reserve its bytes."""
        addr = self.directory.address_of(twid)
        if addr is None:
            return False
        # Ask a live holder to push (prefer the completing worker: its
        # copy is freshest and its notify is already racing the lease).
        holders = self.directory.holders(key)
        order = sorted(holders, key=lambda w: (w != worker_id, -holders[w]))
        for hwid in order:
            hst = self._workers.get(hwid)
            if (
                hwid == twid
                or hst is None
                or hst.dead
                or not hst.runtime.alive
            ):
                continue
            req = getattr(hst.runtime, "push_region_to", None)
            if req is None:
                continue
            req(key, addr)
            self.push_directives += 1
            self._push_inbound[(twid, key)] = _PushInFlight(now, est)
            total = self._push_inflight_bytes.get(twid, 0) + est
            self._push_inflight_bytes[twid] = total
            if total > self.push_inflight_peak.get(twid, 0):
                self.push_inflight_peak[twid] = total
            return True
        return False

    # -- data-plane flow control --------------------------------------------

    def _push_admit_locked(self, twid: int, nbytes: int) -> bool:
        """Ingress-cap admit rule (mirrored by the simulator's
        ``_push_admit``): admit while the target's reserved bytes stay
        within the cap; with nothing in flight one push always goes."""
        cap = self.cfg.push_inflight_cap_bytes
        if cap is None:
            return True
        inflight = self._push_inflight_bytes.get(twid, 0)
        return inflight == 0 or inflight + nbytes <= cap

    def _release_push_locked(self, lkey: tuple[int, Any]) -> None:
        ent = self._push_inbound.pop(lkey, None)
        if ent is None:
            return
        twid = lkey[0]
        left = self._push_inflight_bytes.get(twid, 0) - ent.nbytes
        if left > 0:
            self._push_inflight_bytes[twid] = left
        else:
            self._push_inflight_bytes.pop(twid, None)

    def _expire_pushes_locked(self, now: float) -> None:
        """Reclaim ledger entries whose push evidently never landed
        (holder died mid-send, frame lost): their reserved bytes return
        so the ingress cap cannot leak shut, and the affected targets'
        deferred queues get a drain chance."""
        stale = [
            lkey
            for lkey, ent in self._push_inbound.items()
            if now - ent.t >= 10.0
        ]
        for lkey in stale:
            self._release_push_locked(lkey)
        for twid in {lkey[0] for lkey in stale}:
            self._drain_push_deferred_locked(twid)

    def _drain_push_deferred_locked(self, twid: int) -> None:
        """Re-issue deferred pushes toward ``twid`` as credits allow."""
        q = self._push_deferred.get(twid)
        if not q:
            return
        tst = self._workers.get(twid)
        if tst is None or tst.dead or not tst.runtime.alive:
            self._abort_push_target_locked(twid)
            return
        now = time.monotonic()
        while q:
            src_wid, key = q[0]
            holders = self.directory.holders(key)
            if holders.get(twid):
                # Landed through another route (pull backstop) while
                # queued: the push is moot.
                q.popleft()
                self._push_deferred_keys.discard((twid, key))
                continue
            est = max(holders.values(), default=0)
            if not self._push_admit_locked(twid, est):
                break
            q.popleft()
            self._push_deferred_keys.discard((twid, key))
            if not self._issue_push_locked(src_wid, twid, tst, key, now, est):
                # Every holder died (or lost its data plane) while the
                # directive waited: the push is abandoned — counted, and
                # served by the dependent's pull backstop.
                self.pushes_dropped += 1
        if not q:
            self._push_deferred.pop(twid, None)

    def _abort_push_target_locked(self, twid: int) -> None:
        """Target worker died or left: every reserved or queued push
        toward it is void — release the ledger so the ingress cap can
        never deadlock on a corpse (its dependents re-pull from the
        surviving holders instead)."""
        q = self._push_deferred.pop(twid, None)
        if q:
            self.pushes_dropped += len(q)
            for _, key in q:
                self._push_deferred_keys.discard((twid, key))
        for lkey in [k for k in self._push_inbound if k[0] == twid]:
            self._release_push_locked(lkey)
        # Belt and braces: no ledger entry may outlive the target, so
        # the raw byte counter must not either.
        self._push_inflight_bytes.pop(twid, None)

    def _predict_assignment_locked(self, uids: list) -> dict[int, int]:
        """Which worker will the imminent dispatch lease each of
        ``uids`` to?  Mirrors ``_dispatch_all_locked`` virtually (no
        side effects): locality-aware placement scores pending-queue
        affinity per slack worker; demand-driven mode replays the
        window-filling FIFO walk over the current pending order."""
        live = {
            wid: st
            for wid, st in self._workers.items()
            if not st.dead and st.runtime.alive
        }
        slots = {
            wid: max(self.cfg.window - len(st.leases), 0)
            for wid, st in live.items()
        }
        out: dict[int, int] = {}
        if self.cfg.locality_aware:
            for uid in uids:
                keys = self._input_keys(self.cw.stage_instances[uid])
                best, best_f = None, -1.0
                for wid in live:
                    if slots.get(wid, 0) <= 0:
                        continue
                    f = (
                        self.directory.placement_score(
                            wid, keys, self.cfg.placement.rack_affinity
                        )
                        if keys
                        else 0.0
                    )
                    if f > best_f:
                        best, best_f = wid, f
                if best is not None:
                    out[uid] = best
                    slots[best] -= 1
            return out
        assign: dict[int, int] = {}
        queue = iter([si.uid for si in self._pending])
        for wid in live:
            n = slots.get(wid, 0)
            while n > 0:
                uid = next(queue, None)
                if uid is None:
                    return {u: assign[u] for u in uids if u in assign}
                assign[uid] = wid
                n -= 1
        return {u: assign[u] for u in uids if u in assign}

    def _fetch_region(self, key: RegionKey) -> Any:
        """Region pull: output of a completed upstream op, or None.

        This is the *relay* route — the bytes cross the coordinator —
        kept as the fallback when the holder is dead or unknown; the
        happy path resolves holders (``resolve_regions``) and dials the
        sibling directly.  The Manager's own output copy is tried
        first; after a failover rehydration that copy is gone, so the
        pull falls back to a worker the placement directory records as
        a holder (region-pull RPC via the worker handle).  The holder
        RPCs run *outside* the Manager lock: a slow or hung holder must
        not stall heartbeats and dispatch for every other worker.
        """
        if not (isinstance(key, tuple) and len(key) == 2 and key[0] == "op"):
            return None
        with self._lock:
            oi = self.cw.op_instances.get(key[1])
            if oi is None:
                return None
            outputs = self._stage_outputs.get(oi.stage_instance.uid)
            if outputs and oi.op.name in outputs:
                value = outputs.get(oi.op.name)
                self.relay_regions += 1
                self.relay_bytes += sizeof(value)
                return value
            holders = self._holder_runtimes_locked(key)
        for rt in holders:
            value = rt.pull_region(key)
            if value is not None:
                self.relay_regions += 1
                self.relay_bytes += sizeof(value)
                return value
        return None

    def _fetch_regions(self, keys: list) -> list:
        """Batched region pull: one round-trip serves a whole key batch
        (StagingAgent coalescing / SocketBus ``fetch_regions`` RPC)."""
        return [self._fetch_region(key) for key in keys]

    def _holder_runtimes_locked(
        self, key: RegionKey, exclude: Optional[int] = None
    ) -> list:
        """Live worker handles the directory names as holders of ``key``."""
        out = []
        for wid in self.directory.holders(key):
            if wid == exclude:
                continue
            st = self._workers.get(wid)
            if st is not None and not st.dead and st.runtime.alive:
                out.append(st.runtime)
        return out

    def _pull_from_holder_locked(
        self, key: RegionKey, exclude: Optional[int] = None
    ) -> Any:
        """Synchronous holder pull for the (rare) rehydration push path.

        Runs under the Manager lock — only reached when forwarding to an
        agent-less worker after a failover; proxies cap the RPC timeout
        so a hung holder bounds, not wedges, the control plane.
        """
        for rt in self._holder_runtimes_locked(key, exclude=exclude):
            value = rt.pull_region(key)
            if value is not None:
                return value
        return None

    def _forward_upstream_outputs(self, rt: WorkerRuntime, si: StageInstance) -> None:
        """Provide cross-stage inputs (sink op outputs of upstream stages).

        Workers running a StagingAgent get the *pull* flavor: inputs not
        already staged are left for the agent to prefetch asynchronously
        (submit_stage enqueues the requests), overlapping the copy with
        whatever the lanes are executing.  Agent-less workers get the
        classic synchronous push.

        Delivery is one batched ``forward_inputs`` round-trip per lease:
        the worker marks inputs already staged there (skip-copy; the
        savings are accounted here) and ingests the pushed values —
        on a SocketBus that is a single frame instead of a per-
        dependency mark/provide conversation.
        """
        lazy = getattr(rt, "agent", None) is not None
        items: list[tuple[int, Any, bool, bool]] = []
        sizes: dict[int, int] = {}
        for oi in si.op_instances:
            for dep_uid in oi.deps:
                if dep_uid not in self.cw.op_instances:
                    continue
                dep_oi = self.cw.op_instances[dep_uid]
                if dep_oi.stage_instance.uid == si.uid:
                    continue
                up_uid = dep_oi.stage_instance.uid
                up_outputs = self._stage_outputs.get(up_uid, {})
                if dep_oi.op.name in up_outputs:
                    value = up_outputs[dep_oi.op.name]
                elif up_uid in self._stage_done:
                    # Rehydrated Manager: the output payload died with
                    # the old coordinator.  Lazy workers pull it through
                    # the data plane / fetch_region (both consult the
                    # directory's sibling holders); push-mode workers
                    # need it refetched right now.
                    key = op_key(dep_uid)
                    value = (
                        None
                        if lazy
                        else self._pull_from_holder_locked(
                            key, exclude=rt.worker_id
                        )
                    )
                else:
                    continue  # upstream genuinely not finished yet
                sizes[dep_uid] = (
                    sizeof(value)
                    if value is not None
                    else max(
                        self.directory.holders(op_key(dep_uid)).values(),
                        default=0,
                    )
                )
                push = not lazy and value is not None
                # A predicted push is racing toward this worker for this
                # key: tell it, so its agent defers the duplicate pull.
                # The ledger entry stays until the region_staged credit
                # (or expiry) retires it — the reserved ingress bytes
                # are still on the wire; ``leased`` just stops a
                # re-lease from double-arming the agent's deferral.
                ent = self._push_inbound.get(
                    (rt.worker_id, op_key(dep_uid))
                )
                inbound = lazy and ent is not None and not ent.leased
                if ent is not None:
                    ent.leased = True
                items.append((dep_uid, value if push else None, push, inbound))
        if not items:
            return
        for uid in rt.forward_inputs(items):
            # Already staged on that worker (it ran the upstream, or its
            # agent prefetched it): the copy was skipped — account it.
            self.staged_bytes_avoided += sizes.get(uid, 0)

    def _issue_backups_locked(self) -> None:
        clones_of = getattr(self, "_clones_of", None)
        if clones_of is None:
            clones_of = self._clones_of = {}
        # A probationed worker is excluded: it is the suspected
        # straggler — duplicating tail work onto it defeats the backup.
        idle = [
            (wid, st)
            for wid, st in self._workers.items()
            if not st.dead
            and st.runtime.alive
            and not st.probation
            and not st.leases
        ]
        if not idle:
            return
        outstanding: list[StageInstance] = []
        for st in self._workers.values():
            for uid in st.leases:
                if (
                    uid not in self._stage_done
                    and uid not in self._dup_issued
                    and uid not in clones_of
                ):
                    outstanding.append(self.cw.stage_instances[uid])
        for (wid, st), si in zip(idle, outstanding):
            self._dup_issued.add(si.uid)
            self.duplicated_leases += 1
            self._clone_lease_locked(wid, st, si)

    def _clone_lease_locked(
        self, wid: int, st: _WorkerState, si: StageInstance
    ) -> None:
        """Duplicate ``si`` onto worker ``wid`` as a backup/hedge twin.

        The clone mirrors the original's cross-stage input edges so the
        twin computes on the same upstream outputs (a bare re-instance
        would run its source ops on the raw chunk payload); first
        completion wins through ``_on_stage_complete``'s twin-cancel.
        """
        clones_of = getattr(self, "_clones_of", None)
        if clones_of is None:
            clones_of = self._clones_of = {}
        clone = self.cw._new_stage_instance(si.chunk, si.stage)  # noqa: SLF001
        local = {o.uid for o in si.op_instances}
        orig_by_name = {o.op.name: o for o in si.op_instances}
        for c_oi in clone.op_instances:
            orig = orig_by_name[c_oi.op.name]
            c_oi.deps |= orig.deps - local
            c_oi.dep_names.update(
                {u: n for u, n in orig.dep_names.items() if u not in local}
            )
        clones_of[clone.uid] = si.uid
        st.leases.add(clone.uid)
        self._lease_t[(wid, clone.uid)] = time.monotonic()
        self._forward_upstream_outputs(st.runtime, clone)
        st.runtime.submit_stage(clone)

    # -- gray-failure resilience ----------------------------------------------

    def _stage_hist(self, stage_name: str):
        """Manager-side stage-latency histogram (lease to completion),
        one per stage name — the distribution the hedge p99 trigger
        reads (queueing included: a hedge covers the whole wait)."""
        return self.metrics.histogram(f"manager.stage_latency_s.{stage_name}")

    def _exec_hist(self, stage_name: str):
        """Worker-reported stage *execution* seconds (queueing
        excluded), one per stage name — the health ratio's expected
        baseline.  Separate from ``_stage_hist``: judging a probe
        lease (empty queue) against queue-inflated latencies made
        probation exit a coin flip."""
        return self.metrics.histogram(f"manager.stage_exec_s.{stage_name}")

    def _update_probation_locked(self, wid: int, st: _WorkerState) -> None:
        """Probation state machine, advanced on each health observation:
        a clean worker whose score crosses the entry threshold (with
        enough samples to be credible) gets benched; a probing worker
        whose score recovers — judged on its own probe completions, at
        least two — rejoins at full weight."""
        s = self.health.score(wid, self.cfg.heartbeat_timeout)
        if not st.probation:
            if (
                self.health.samples(wid) >= self.cfg.probation_min_samples
                and s >= self.cfg.probation_ratio
            ):
                self._enter_probation_locked(wid, st, s, "runtime ratio")
            return
        st.probe_completions += 1
        if (
            st.probe_completions >= 2
            and s <= self.cfg.probation_recover_ratio
        ):
            st.probation = False
            st.hedged_against = 0
            self.probation_exits += 1
            self.health.reset(wid)
            if self.recorder is not None:
                self.recorder.note(
                    "probation_exit", worker=wid, score=round(s, 3),
                    probes=st.probe_completions,
                )

    def _enter_probation_locked(
        self, wid: int, st: _WorkerState, score: float, reason: str
    ) -> None:
        """Bench a gray-failing worker: its outstanding leases re-queue
        to healthy workers (the same atomic recovery a drain performs)
        but the worker stays *registered* with a single probe lease —
        recovery is observable and rejoin automatic, distinct from
        heartbeat death which assumes the work is lost."""
        if st.probation:
            return
        st.probation = True
        st.probe_completions = 0
        st.hedged_against = 0
        st.last_heartbeat = time.monotonic()
        self.probations += 1
        if self.recorder is not None:
            self.recorder.note(
                "probation_enter", worker=wid, score=round(score, 3),
                reason=reason,
            )
        for uid in sorted(st.leases):
            self._lease_t.pop((wid, uid), None)
            if uid in self._stage_done:
                continue
            try:
                st.runtime.cancel_stage(uid)
            except Exception:
                pass  # runtime may already be gone
            # A twin of the same primary already live elsewhere (or
            # queued) covers this lease — re-queueing would make a
            # third runner for no added protection.
            pu = self._clone_map().get(uid, uid)
            clone_uids = {c for c, p in self._clone_map().items() if p == pu}
            active = ({pu} | clone_uids) - {uid}
            covered = any(
                active & ws.leases
                for ws in self._workers.values()
                if ws is not st
            ) or any(p.uid in active for p in self._pending)
            if not covered:
                self.recovered_leases += 1
                self._push_pending_locked(self.cw.stage_instances[pu])
        st.leases.clear()

    def _issue_hedges_locked(self, now: float) -> None:
        """Percentile hedging: a running lease whose age exceeds its
        stage's measured latency p99 × ``hedge_slack`` gets a twin on
        the healthiest worker with window slack — first completion wins
        through the existing twin-cancel/exactly-once path.  This
        generalizes tail-only backup tasks: hedges fire mid-run,
        triggered by the latency histogram instead of queue drain, and
        are health-routed away from suspects."""
        slack = self.cfg.hedge_slack
        if slack is None:
            return
        candidates: list[tuple[int, _WorkerState, StageInstance, float, float, float]] = []
        for wid, st in self._workers.items():
            if st.dead or not st.runtime.alive:
                continue
            for uid in st.leases:
                if (
                    uid in self._stage_done
                    or uid in self._dup_issued
                    or uid in self._clone_map()
                ):
                    continue
                t0 = self._lease_t.get((wid, uid))
                if t0 is None:
                    continue
                si = self.cw.stage_instances[uid]
                hist = self._stage_hist(si.stage.name)
                if hist.count < self.cfg.hedge_min_samples:
                    continue
                p99 = hist.percentile(0.99)
                if p99 is None or now - t0 <= p99 * slack:
                    continue
                p50 = hist.percentile(0.5)
                candidates.append((wid, st, si, now - t0, p99, p50 or 0.0))
        for wid, st, si, age, p99, p50 in candidates:
            if si.uid not in st.leases or si.uid in self._dup_issued:
                continue  # probation entry below re-queued it already
            target = self._pick_hedge_target_locked(exclude=wid)
            if target is None:
                return  # nobody has slack: retry next monitor tick
            twid, tst = target
            self._dup_issued.add(si.uid)
            self.duplicated_leases += 1
            self.hedged_leases += 1
            self._clone_lease_locked(twid, tst, si)
            if self.recorder is not None:
                self.recorder.note(
                    "hedge", uid=si.uid, slow_worker=wid, target=twid,
                    age_s=round(age, 4), p99_s=round(p99, 4),
                )
            # A lease blowing p99 × slack is itself a health
            # observation — it arrives *before* the slow completion
            # would, which is exactly when detection matters.
            if self.cfg.health_scoring:
                st.hedged_against += 1
                if p50 > 0.0:
                    self.health.observe(wid, age / p50)
                if (
                    not st.probation
                    and st.hedged_against >= self.cfg.probation_after_hedges
                ):
                    self._enter_probation_locked(
                        wid, st,
                        self.health.score(wid, self.cfg.heartbeat_timeout),
                        "hedged leases",
                    )

    def _pick_hedge_target_locked(
        self, exclude: int
    ) -> Optional[tuple[int, _WorkerState]]:
        """Healthiest live worker with window slack, excluding the
        suspect itself and anything on probation."""
        best: Optional[tuple[tuple, int, _WorkerState]] = None
        for twid, tst in self._workers.items():
            if (
                twid == exclude
                or tst.dead
                or not tst.runtime.alive
                or tst.probation
            ):
                continue
            # One overflow slot past the window: under saturation every
            # healthy window is full, and a hedge that must wait for a
            # free slot defeats its purpose (first completion wins and
            # the twin is cancelled, so the overflow is transient).
            cap = self._window_for_locked(twid, tst) + 1
            free = cap - len(tst.leases)
            if free <= 0:
                continue
            w = (
                self.health.weight(twid, self.cfg.heartbeat_timeout)
                if self.cfg.health_scoring
                else 1.0
            )
            key = (w, free, -twid)
            if best is None or key > best[0]:
                best = (key, twid, tst)
        if best is None:
            return None
        return best[1], best[2]

    def _check_done_locked(self) -> None:
        if self._streaming:
            return  # open stream: more requests may still arrive
        clones = set(self._clone_map())
        for uid in self.cw.stage_instances:
            if uid in clones:
                continue
            # A quarantined stage is terminally accounted: completed-or-
            # quarantined is the exactly-once invariant, and a poison
            # chunk must not wedge the run.
            if uid not in self._stage_done and uid not in self._quarantined:
                return
        self._done_event.set()

    def _monitor_loop(self) -> None:
        """Heartbeat watchdog: reap dead workers, re-lease their work."""
        while not self._stop_monitor and not self._done_event.is_set():
            time.sleep(self.cfg.poll_interval)
            now = time.monotonic()
            newly_q: list[int] = []
            with self._lock:
                # Reclaim lost-push reservations even when no further
                # stage completion would run the predictor's sweep.
                self._expire_pushes_locked(now)
                any_live = any(
                    not st.dead and st.runtime.alive
                    for st in self._workers.values()
                )
                for wid, st in self._workers.items():
                    if st.dead:
                        # Last-resort rejoin: every worker has been
                        # reaped yet this one's runtime reports alive.
                        # Without it a cluster whose every (healthy but
                        # slow) worker was slandered wedges with work
                        # pending and nobody to run it.  With other
                        # live workers, exclusion stands — a genuinely
                        # wedged worker must not be re-leased work; it
                        # rejoins only via a fresh heartbeat
                        # (_heartbeat), which proves progress.
                        if not any_live and st.runtime.alive:
                            st.dead = False
                            st.last_heartbeat = now
                            any_live = True
                        continue
                    inflight = bool(st.leases)
                    # A probationed worker is already contained (one
                    # probe lease, hedging covers it): reaping it again
                    # would double-drain work the probation entry just
                    # re-queued.  It keeps a long-grace backstop so a
                    # probe that wedges outright still gets reaped.
                    grace = self.cfg.heartbeat_timeout * (
                        4.0 if st.probation else 1.0
                    )
                    expired = now - st.last_heartbeat > grace
                    if not st.runtime.alive or (inflight and expired):
                        st.dead = True
                        self.directory.drop_worker(wid)
                        # Pushes toward the corpse are void: release
                        # their credits, drop its deferred queue.
                        self._abort_push_target_locked(wid)
                        # Each lost lease charges the dead worker against
                        # the chunk's attempt budget: a chunk that keeps
                        # killing workers quarantines instead of being
                        # re-leased forever.  Snapshot: crossing the
                        # budget cancels leases (mutates this set).
                        for uid in list(st.leases):
                            self._lease_t.pop((wid, uid), None)
                            if uid not in self._stage_done and (
                                self._charge_attempt_locked(
                                    wid, uid, "worker lost mid-lease",
                                    newly_q,
                                )
                            ):
                                self.recovered_leases += 1
                                self._push_pending_locked(
                                    self.cw.stage_instances[uid]
                                )
                        st.leases.clear()
                self._issue_hedges_locked(now)
                self._dispatch_all_locked()
                self._check_done_locked()
            self._fire_failure_hooks(newly_q)
