"""Demand-driven Manager (paper §III-B, Fig 4) with fault tolerance.

The Manager has the overall view of the runtime: it instantiates the
abstract workflow, tracks inter-stage dependencies, and leases stage
instances to Workers demand-driven — each Worker holds at most
``window`` leases and requests more as leases complete (the paper's
*Window size*, §V-F).

Beyond the paper, the Manager provides the fault-tolerance required for
thousand-node deployments:

* **heartbeats** — a Worker that stops reporting is declared dead and
  its outstanding leases return to the queue (chunk processing is
  idempotent, so re-execution is safe);
* **straggler backup tasks** — at the tail of a run, outstanding leases
  are duplicated onto idle Workers and the first completion wins;
* **elastic membership** — Workers may register/deregister mid-run;
  the lease queue simply redistributes.

In a single process the Worker objects are invoked directly; on a
cluster the same protocol runs over MPI/gRPC — the Manager class is
transport-agnostic (``transport`` hooks).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .workflow import ConcreteWorkflow, StageInstance
from .worker import WorkerRuntime

__all__ = ["Manager", "ManagerConfig"]


@dataclass
class ManagerConfig:
    window: int = 4                  # leases in flight per worker
    heartbeat_timeout: float = 60.0  # seconds without progress => dead
    backup_tasks: bool = True       # duplicate tail leases
    poll_interval: float = 0.01


@dataclass
class _WorkerState:
    runtime: WorkerRuntime
    leases: set[int] = field(default_factory=set)
    last_heartbeat: float = field(default_factory=time.monotonic)
    dead: bool = False


class Manager:
    def __init__(self, workflow: ConcreteWorkflow, cfg: ManagerConfig | None = None):
        self.cw = workflow
        self.cfg = cfg or ManagerConfig()
        self._lock = threading.RLock()
        self._workers: dict[int, _WorkerState] = {}
        self._pending: list[StageInstance] = []
        self._stage_done: set[int] = set()
        self._stage_outputs: dict[int, dict[str, Any]] = {}
        self._dup_issued: set[int] = set()
        self.recovered_leases = 0
        self.duplicated_leases = 0
        self._done_event = threading.Event()
        self._monitor: Optional[threading.Thread] = None
        self._stop_monitor = False

    # -- membership -------------------------------------------------------

    def register_worker(self, runtime: WorkerRuntime) -> None:
        runtime.on_stage_complete = self._make_completion_cb(runtime.worker_id)
        runtime.on_heartbeat = self._heartbeat  # per-op liveness pings
        with self._lock:
            self._workers[runtime.worker_id] = _WorkerState(runtime=runtime)

    def _heartbeat(self, worker_id: int) -> None:
        with self._lock:
            st = self._workers.get(worker_id)
            if st is not None:
                st.last_heartbeat = time.monotonic()

    def deregister_worker(self, worker_id: int) -> None:
        """Elastic scale-down: return the worker's leases to the queue."""
        with self._lock:
            st = self._workers.pop(worker_id, None)
            if st is None:
                return
            for uid in st.leases:
                if uid not in self._stage_done:
                    self._pending.append(self.cw.stage_instances[uid])
            self._dispatch_all_locked()

    # -- execution -----------------------------------------------------------

    def run(self, timeout: float = 120.0) -> bool:
        """Lease everything and block until the workflow completes."""
        with self._lock:
            self._pending.extend(self.cw.ready_stage_instances(self._stage_done))
            self._dispatch_all_locked()
        self._stop_monitor = False
        self._monitor = threading.Thread(target=self._monitor_loop, daemon=True)
        self._monitor.start()
        ok = self._done_event.wait(timeout=timeout)
        self._stop_monitor = True
        self._monitor.join(timeout=2.0)
        return ok

    def progress(self) -> tuple[int, int]:
        with self._lock:
            total = sum(
                1 for uid in self.cw.stage_instances if uid not in self._clone_map()
            )
            return len(self._stage_done - set(self._clone_map())), total

    def stage_outputs(self, uid: int) -> dict[str, Any]:
        with self._lock:
            return self._stage_outputs.get(uid, {})

    # -- internals ---------------------------------------------------------------

    def _clone_map(self) -> dict[int, int]:
        return getattr(self, "_clones_of", {})

    def _make_completion_cb(self, worker_id: int):
        def cb(si: StageInstance, outputs: dict[str, Any]) -> None:
            self._on_stage_complete(worker_id, si, outputs)

        return cb

    def _on_stage_complete(
        self, worker_id: int, si: StageInstance, outputs: dict[str, Any]
    ) -> None:
        with self._lock:
            st = self._workers.get(worker_id)
            if st is not None:
                st.last_heartbeat = time.monotonic()
            clones_of = self._clone_map()
            primary_uid = clones_of.get(si.uid, si.uid)
            if primary_uid in self._stage_done:
                return  # a backup twin already completed this lease
            self._stage_done.add(primary_uid)
            if si.uid != primary_uid:
                self._stage_done.add(si.uid)
            self._stage_outputs[primary_uid] = outputs
            for wst in self._workers.values():
                wst.leases.discard(si.uid)
                wst.leases.discard(primary_uid)
                # Cancel twins on other workers.
                for c_uid, p_uid in clones_of.items():
                    if p_uid == primary_uid and c_uid in wst.leases:
                        wst.runtime.cancel_stage(c_uid)
                        wst.leases.discard(c_uid)
            primary = self.cw.stage_instances[primary_uid]
            # Unlock downstream stage instances and forward their inputs.
            for dep_uid in primary.dependents:
                dsi = self.cw.stage_instances[dep_uid]
                if dsi.deps.issubset(self._stage_done) and dep_uid not in self._stage_done:
                    already = any(
                        dep_uid in w.leases for w in self._workers.values()
                    ) or any(p.uid == dep_uid for p in self._pending)
                    if not already:
                        self._pending.append(dsi)
            self._dispatch_all_locked()
            self._check_done_locked()

    def _dispatch_all_locked(self) -> None:
        for st in self._workers.values():
            if st.dead or not st.runtime.alive:
                continue
            while len(st.leases) < self.cfg.window and self._pending:
                si = self._pending.pop(0)
                st.leases.add(si.uid)
                self._forward_upstream_outputs(st.runtime, si)
                st.runtime.submit_stage(si)
        if self.cfg.backup_tasks and not self._pending:
            self._issue_backups_locked()

    def _forward_upstream_outputs(self, rt: WorkerRuntime, si: StageInstance) -> None:
        """Provide cross-stage inputs (sink op outputs of upstream stages)."""
        for oi in si.op_instances:
            for dep_uid in oi.deps:
                if dep_uid not in self.cw.op_instances:
                    continue
                dep_oi = self.cw.op_instances[dep_uid]
                if dep_oi.stage_instance.uid != si.uid:
                    up_outputs = self._stage_outputs.get(
                        dep_oi.stage_instance.uid, {}
                    )
                    if dep_oi.op.name in up_outputs:
                        rt.provide_input(dep_uid, up_outputs[dep_oi.op.name])

    def _issue_backups_locked(self) -> None:
        clones_of = getattr(self, "_clones_of", None)
        if clones_of is None:
            clones_of = self._clones_of = {}
        idle = [
            st
            for st in self._workers.values()
            if not st.dead and st.runtime.alive and not st.leases
        ]
        if not idle:
            return
        outstanding: list[StageInstance] = []
        for st in self._workers.values():
            for uid in st.leases:
                if (
                    uid not in self._stage_done
                    and uid not in self._dup_issued
                    and uid not in clones_of
                ):
                    outstanding.append(self.cw.stage_instances[uid])
        for st, si in zip(idle, outstanding):
            self._dup_issued.add(si.uid)
            self.duplicated_leases += 1
            clone = self.cw._new_stage_instance(si.chunk, si.stage)  # noqa: SLF001
            clones_of[clone.uid] = si.uid
            st.leases.add(clone.uid)
            self._forward_upstream_outputs(st.runtime, clone)
            st.runtime.submit_stage(clone)

    def _check_done_locked(self) -> None:
        clones = set(self._clone_map())
        for uid in self.cw.stage_instances:
            if uid in clones:
                continue
            if uid not in self._stage_done:
                return
        self._done_event.set()

    def _monitor_loop(self) -> None:
        """Heartbeat watchdog: reap dead workers, re-lease their work."""
        while not self._stop_monitor and not self._done_event.is_set():
            time.sleep(self.cfg.poll_interval)
            now = time.monotonic()
            with self._lock:
                for st in self._workers.values():
                    if st.dead:
                        continue
                    inflight = bool(st.leases)
                    expired = (
                        now - st.last_heartbeat > self.cfg.heartbeat_timeout
                    )
                    if not st.runtime.alive or (inflight and expired):
                        st.dead = True
                        for uid in st.leases:
                            if uid not in self._stage_done:
                                self.recovered_leases += 1
                                self._pending.append(
                                    self.cw.stage_instances[uid]
                                )
                        st.leases.clear()
                self._dispatch_all_locked()
                self._check_done_locked()
