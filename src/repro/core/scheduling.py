"""Within-node operation scheduling: FCFS, PATS, and data-locality (DL).

This module contains the decision logic only — it is shared verbatim by
the real threaded Worker (``core/worker.py``) and by the discrete-event
cluster simulator (``core/simulator.py``), so scheduling behaviour
measured in the simulator is the behaviour of the production code.

Policies (paper §IV):

* ``fcfs``  — FIFO queue; the next idle device takes the head.
* ``pats``  — queue kept sorted by estimated accelerator speedup.  An
  idle accelerator takes the *maximum*-speedup ready tuple, an idle CPU
  core the *minimum*-speedup tuple.  Only the relative order of the
  estimates matters (paper §V-G).

Data-locality conscious assignment (DL, paper §IV-C) is orthogonal and
applies to accelerator lanes: prefer a ready dependent whose inputs are
already resident in that accelerator's memory.  When speedups are
known, the dependent wins only if ``S_d >= S_q * (1 - transferImpact)``
where ``S_q`` is the best non-resident candidate and ``transferImpact``
is the fraction of that candidate's execution time spent moving data.

Two extensions for the device-resident fast path:

* **chain affinity** — when the runtime chains operations on the
  device (outputs stay resident, no host materialization), a resident
  dependent additionally skips its *own* transfer fraction, so its
  effective speedup is ``S_d / (1 - transferImpact_d)``.  Enabled via
  ``chain_affinity`` in [0, 1] scaling that bonus.
* **micro-batching** — :meth:`ReadyScheduler.pop_batch` pops up to
  ``limit`` ready instances of the *same operation* in one decision so
  an accelerator lane can execute them as a single batched kernel call
  and amortize its launch overhead.

One extension for the serving front end (:mod:`repro.serving`):

* **deadline tier (EDF)** — operation instances carrying a deadline
  (inherited from their serving request) form a tier *above* the
  FCFS/PATS order: an idle lane always takes the earliest-deadline
  work first, and only falls back to the batch queue when no deadline
  work is ready.  Within one deadline group (all ops of one request
  share its deadline) the PATS rule still applies — accelerators take
  the max-speedup member, host cores the min — so EDF decides *which
  request* runs next and PATS decides *where* its ops run.
* **slack band** — with ``edf_slack_band`` set, strict EDF preemption
  applies only to deadline work that is *at risk* (earliest deadline
  within ``band`` seconds of now).  Deadline work with ample slack no
  longer starves the locality/PATS order: the batch tier runs with its
  normal placement quality and the EDF tier reclaims priority exactly
  when urgency demands it.  ``None`` keeps the strict-EDF behavior.
"""

from __future__ import annotations

import bisect
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

from .workflow import OperationInstance

__all__ = ["ReadyScheduler", "SchedulerStats", "HOST_KIND"]

HOST_KIND = "cpu"


@dataclass
class SchedulerStats:
    """Per-(op name, lane kind) assignment counts — Fig 10/12 profiles."""

    assigned: dict[tuple[str, str], int] = field(default_factory=dict)
    reuse_hits: int = 0
    reuse_misses: int = 0
    # Micro-batched dispatch: batched pops (>1 member) and the total
    # number of op instances dispatched inside those batches.
    batches: int = 0
    batched_ops: int = 0
    # Serving: pops served from the deadline (EDF) tier.
    deadline_pops: int = 0
    # Slack-band hybrid: pops where deadline work was queued but had
    # enough slack that the locality/PATS order was served instead.
    slack_deferrals: int = 0

    def record(self, op_name: str, lane_kind: str) -> None:
        key = (op_name, lane_kind)
        self.assigned[key] = self.assigned.get(key, 0) + 1

    def profile(self) -> dict[str, dict[str, int]]:
        out: dict[str, dict[str, int]] = {}
        for (op, kind), n in self.assigned.items():
            out.setdefault(op, {})[kind] = n
        return out

    def accel_fraction(self, accel_kind: str = "gpu") -> dict[str, float]:
        prof = self.profile()
        return {
            op: kinds.get(accel_kind, 0) / max(sum(kinds.values()), 1)
            for op, kinds in prof.items()
        }

    def bind(self, registry, prefix: str = "scheduler") -> "SchedulerStats":
        """Serve the scalar counters from a shared ``MetricsRegistry``.

        The int fields are replaced with the registry's int-like
        counter cells (same ``+=`` call sites, comparisons, and reads
        — see :mod:`repro.telemetry.metrics`); ``assigned`` stays a
        plain dict (its per-(op, lane) keys are a profile, not a
        scalar metric).  Unbound (the default, e.g. the thousands of
        per-node schedulers inside a simulation) nothing changes and
        increments stay plain-int cheap.
        """
        for name in ("reuse_hits", "reuse_misses", "batches",
                     "batched_ops", "deadline_pops", "slack_deferrals"):
            cell = registry.counter(f"{prefix}.{name}")
            cell.inc(int(getattr(self, name)))
            setattr(self, name, cell)
        return self


class _SortedTasks:
    """Tasks kept sorted by (speedup, seq).  O(log n) insert/remove."""

    def __init__(self) -> None:
        self._keys: list[tuple[float, int]] = []
        self._tasks: list[OperationInstance] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._tasks)

    def add(self, task: OperationInstance) -> None:
        key = (task.speedup, self._seq)
        self._seq += 1
        i = bisect.bisect(self._keys, key)
        self._keys.insert(i, key)
        self._tasks.insert(i, task)

    def pop_min(self) -> OperationInstance:
        self._keys.pop(0)
        return self._tasks.pop(0)

    def pop_max(self) -> OperationInstance:
        self._keys.pop()
        return self._tasks.pop()

    def peek_max(self) -> OperationInstance:
        return self._tasks[-1]

    def remove(self, task: OperationInstance) -> None:
        # speedup is not mutated while queued, so key search is exact.
        lo = bisect.bisect_left(self._keys, (task.speedup, -1))
        for i in range(lo, len(self._tasks)):
            if self._tasks[i] is task:
                del self._keys[i]
                del self._tasks[i]
                return
            if self._keys[i][0] > task.speedup:
                break
        raise ValueError("task not in queue")

    def __iter__(self) -> Iterable[OperationInstance]:
        return iter(self._tasks)


class _DeadlineTasks:
    """Deadline-carrying tasks sorted by (deadline, speedup, seq).

    The earliest-deadline *group* (ops sharing one request's deadline)
    is served first; within the group an accelerator lane takes the
    max-speedup member and a host lane the min — the PATS rule applied
    inside the EDF tier.
    """

    def __init__(self) -> None:
        self._keys: list[tuple[float, float, int]] = []
        self._tasks: list[OperationInstance] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._tasks)

    def __iter__(self) -> Iterable[OperationInstance]:
        return iter(self._tasks)

    def add(self, task: OperationInstance) -> None:
        key = (float(task.deadline), task.speedup, self._seq)
        self._seq += 1
        i = bisect.bisect(self._keys, key)
        self._keys.insert(i, key)
        self._tasks.insert(i, task)

    def peek_deadline(self) -> float:
        return self._keys[0][0]

    def pop_for(self, lane_kind: str) -> OperationInstance:
        d0 = self._keys[0][0]
        # End of the earliest-deadline group.
        hi = bisect.bisect_right(self._keys, (d0, float("inf"), 1 << 62))
        i = 0 if lane_kind == HOST_KIND else hi - 1
        self._keys.pop(i)
        return self._tasks.pop(i)

    def remove(self, task: OperationInstance) -> None:
        lo = bisect.bisect_left(
            self._keys, (float(task.deadline), task.speedup, -1)
        )
        for i in range(lo, len(self._tasks)):
            if self._tasks[i] is task:
                del self._keys[i]
                del self._tasks[i]
                return
        raise ValueError("task not in deadline queue")


class ReadyScheduler:
    """Queue of ready ``(data chunk, operation)`` tuples + pop policy."""

    def __init__(self, policy: str = "fcfs", locality: bool = False,
                 speedups_known: bool = True, chain_affinity: float = 0.0,
                 deadline_aware: bool = True, registry=None,
                 edf_slack_band: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None):
        if policy not in ("fcfs", "pats"):
            raise ValueError(f"unknown policy {policy!r}")
        self.policy = policy
        self.locality = locality
        # DL degrades gracefully when estimates are unavailable: always
        # prefer reuse (paper §IV-C, first case).
        self.speedups_known = speedups_known
        # Device-resident chaining recovers the dependent's own transfer
        # fraction on top of the classic DL rule (0 = plain DL).
        self.chain_affinity = chain_affinity
        # Serving deadline tier: tasks with a deadline are popped EDF,
        # ahead of the batch queue.  False = deadlines ignored (the
        # FIFO baseline the serving benchmarks compare against).
        self.deadline_aware = deadline_aware
        # Slack-aware EDF hybrid: strict EDF preemption only when the
        # earliest deadline is within this many seconds; otherwise the
        # locality/PATS order runs first (None = always preempt).  The
        # clock is injectable so the simulator can drive it with
        # virtual time; deadlines must be on the same clock.
        self.edf_slack_band = edf_slack_band
        self.clock: Callable[[], float] = clock or time.monotonic
        self.stats = SchedulerStats()
        if registry is not None:
            self.stats.bind(registry)
        self._fifo: deque[OperationInstance] = deque()
        self._sorted = _SortedTasks()
        self._edf = _DeadlineTasks()

    # -- queue maintenance ---------------------------------------------------

    def push(self, task: OperationInstance) -> None:
        if self.deadline_aware and task.deadline is not None:
            self._edf.add(task)
        elif self.policy == "pats":
            self._sorted.add(task)
        else:
            self._fifo.append(task)

    def __len__(self) -> int:
        n = len(self._sorted) if self.policy == "pats" else len(self._fifo)
        return n + len(self._edf)

    def __bool__(self) -> bool:
        return len(self) > 0

    # -- dispatch --------------------------------------------------------------

    def pop(
        self,
        lane_kind: str,
        resident_producers: Optional[set[int]] = None,
    ) -> Optional[OperationInstance]:
        """Select the next tuple for an idle lane of ``lane_kind``.

        ``resident_producers`` — uids of op instances whose outputs are
        already in this lane's device memory (accelerator lanes only).
        """
        if not self:
            return None
        task: Optional[OperationInstance]
        if self._edf:
            # Deadline tier first: the most urgent request's ops beat
            # any batch work, whatever its speedup or residency — unless
            # a slack band says the earliest deadline is not yet at
            # risk AND batch work exists to fill the lane (the hybrid
            # stays work-conserving: an empty batch tier always serves
            # deadline work regardless of slack).
            band = self.edf_slack_band
            batch_n = (
                len(self._sorted) if self.policy == "pats" else len(self._fifo)
            )
            if (
                band is None
                or batch_n == 0
                or self._edf.peek_deadline() - self.clock() <= band
            ):
                task = self._edf.pop_for(lane_kind)
                self.stats.deadline_pops += 1
                self.stats.record(task.op.name, lane_kind)
                return task
            self.stats.slack_deferrals += 1
        if self.locality and lane_kind != HOST_KIND and resident_producers:
            task = self._pop_locality(lane_kind, resident_producers)
        elif self.policy == "pats":
            task = (
                self._sorted.pop_min()
                if lane_kind == HOST_KIND
                else self._sorted.pop_max()
            )
        else:
            task = self._fifo.popleft()
        if task is not None:
            self.stats.record(task.op.name, lane_kind)
        return task

    def batch_limit(self, micro_batch: int, idle_lanes: int) -> int:
        """Work-conserving batch depth for one idle accelerator lane.

        Never batch deeper than the ready queue can still feed the
        other idle lanes — amortization must not steal their
        parallelism.  Shared by the threaded worker and the simulator
        so measured batching behaviour is production behaviour.
        """
        return min(micro_batch, max(1, len(self) // max(idle_lanes, 1)))

    def pop_batch(
        self,
        lane_kind: str,
        resident_producers: Optional[set[int]] = None,
        *,
        limit: int = 1,
        batchable: Optional[Callable[[OperationInstance], int]] = None,
    ) -> list[OperationInstance]:
        """Pop up to ``limit`` ready instances of the *same operation*.

        The head is selected with the normal policy (PATS/FCFS + DL);
        when it is batchable, further queued instances of the same op
        join it regardless of queue position — they would execute with
        identical kernels anyway, and one batched launch amortizes the
        dispatch overhead (latency tradeoff measured in the simulator's
        batched-runtime curves).

        ``batchable(head)`` returns the head op's own batch cap (its
        variant's ``max_batch``; <= 1 disables batching) — a batched
        implementation must never receive more contexts than its
        declared maximum.
        """
        first = self.pop(lane_kind, resident_producers)
        if first is None:
            return []
        batch = [first]
        if batchable is not None:
            limit = min(limit, int(batchable(first)))
        if limit <= 1:
            return batch
        # Urgent (EDF-tier) members join the batch first: a batched
        # launch that would run anyway should carry the deadline work.
        pool = list(self._edf)
        pool += list(self._sorted) if self.policy == "pats" else list(self._fifo)
        for task in pool:
            if len(batch) >= limit:
                break
            if task.op.name != first.op.name:
                continue
            self._remove(task)
            self.stats.record(task.op.name, lane_kind)
            batch.append(task)
        if len(batch) > 1:
            self.stats.batches += 1
            self.stats.batched_ops += len(batch)
        return batch

    def reestimate(
        self, estimate: Callable[[OperationInstance], float]
    ) -> None:
        """Refresh queued tasks' speedup estimates and restore order.

        Called when the online EMA estimator (``FunctionVariant.
        observe_runtime``) shifts an estimate: PATS keeps the ready
        queue sorted by speedup, so already-queued instances must be
        re-keyed or the queue order goes stale against the estimates.
        """
        if self._edf:
            # Deadline keys embed the speedup (PATS-in-tier tie-break):
            # re-key the EDF queue alongside the batch queue.
            urgent = list(self._edf)
            for task in urgent:
                task.speedup = estimate(task)
            fresh_edf = _DeadlineTasks()
            for task in urgent:
                fresh_edf.add(task)
            self._edf = fresh_edf
        if self.policy != "pats":
            for task in self._fifo:
                task.speedup = estimate(task)
            return
        tasks = list(self._sorted)
        for task in tasks:
            task.speedup = estimate(task)
        fresh = _SortedTasks()
        for task in tasks:
            fresh.add(task)
        self._sorted = fresh

    def _chained_speedup(self, task: OperationInstance) -> float:
        """Effective speedup of a resident dependent under chaining:
        its inputs need no upload and its output stays resident, so the
        transfer fraction of its own runtime is recovered."""
        return task.speedup / max(
            1.0 - self.chain_affinity * task.transfer_impact, 1e-9
        )

    def _pop_locality(
        self, lane_kind: str, resident: set[int]
    ) -> Optional[OperationInstance]:
        pool = list(self._sorted) if self.policy == "pats" else list(self._fifo)
        reusing = [t for t in pool if t.deps & resident]
        if not reusing:
            self.stats.reuse_misses += 1
            return self._pop_plain(lane_kind)
        if self.policy == "fcfs" or not self.speedups_known:
            # No (usable) estimates: reuse always wins.
            choice = reusing[0]
            self._remove(choice)
            self.stats.reuse_hits += 1
            return choice
        # PATS + DL: best dependent vs best non-resident candidate.
        best_dep = max(reusing, key=self._chained_speedup)
        non_reusing = [t for t in pool if not (t.deps & resident)]
        if not non_reusing:
            self._remove(best_dep)
            self.stats.reuse_hits += 1
            return best_dep
        best_q = max(non_reusing, key=lambda t: t.speedup)
        if self._chained_speedup(best_dep) >= best_q.speedup * (
            1.0 - best_q.transfer_impact
        ):
            self._remove(best_dep)
            self.stats.reuse_hits += 1
            return best_dep
        self._remove(best_q)
        self.stats.reuse_misses += 1
        return best_q

    def _pop_plain(self, lane_kind: str) -> Optional[OperationInstance]:
        if self.policy == "pats":
            return (
                self._sorted.pop_min()
                if lane_kind == HOST_KIND
                else self._sorted.pop_max()
            )
        return self._fifo.popleft()

    def _remove(self, task: OperationInstance) -> None:
        if self.deadline_aware and task.deadline is not None:
            self._edf.remove(task)
        elif self.policy == "pats":
            self._sorted.remove(task)
        else:
            self._fifo.remove(task)
