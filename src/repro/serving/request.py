"""Serving request objects: what a tenant submits and what it gets back.

A :class:`ServeRequest` tracks one tile/pipeline request through the
gateway: admission (or shed), weighted-fair queueing, dispatch into
the Manager as a freshly instantiated pipeline replica, and
completion.  Latency is measured arrival-to-done (queueing included —
that is the number a serving SLO is written against), and the
request's absolute deadline is inherited by every stage instance of
its pipeline so the Manager's EDF tier and the per-node scheduler can
order work by urgency end to end.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Optional

__all__ = ["ServeRequest", "QUEUED", "RUNNING", "DONE", "SHED", "FAILED"]

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
SHED = "shed"
#: Terminal failure: the request's pipeline was quarantined (poison
#: chunk / attempt budget exhausted) — it will never complete, and the
#: tenant gets a verdict instead of a hang.
FAILED = "failed"


@dataclass
class ServeRequest:
    """One admitted (or shed) request.

    ``deadline`` is absolute on the gateway's clock; ``cost`` is the
    estimated service time in seconds (the WFQ charge and the
    admission estimated-work unit).
    """

    req_id: int
    tenant: str
    chunk: Any
    arrival: float
    cost: float = 1.0
    deadline: Optional[float] = None
    state: str = QUEUED
    t_dispatch: Optional[float] = None
    t_done: Optional[float] = None
    #: terminal stage instances still outstanding (gateway internal).
    remaining: int = 0
    #: uids of the stage instances backing this request.
    stage_uids: tuple[int, ...] = ()
    #: terminal error detail (FAILED requests only).
    error: Optional[str] = None
    #: root trace context (telemetry.SpanContext) when tracing is on —
    #: every stage/op/region span of this request chains under it.
    trace: Any = None
    _done_event: threading.Event = field(
        default_factory=threading.Event, repr=False
    )

    @property
    def accepted(self) -> bool:
        return self.state != SHED

    @property
    def latency(self) -> Optional[float]:
        """Arrival-to-completion seconds (None while in flight)."""
        if self.t_done is None:
            return None
        return self.t_done - self.arrival

    @property
    def tardiness(self) -> Optional[float]:
        """Seconds past the deadline (0 when met; None = no verdict)."""
        if self.deadline is None or self.t_done is None:
            return None
        return max(0.0, self.t_done - self.deadline)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the request completes (or was shed)."""
        if self.state == SHED:
            return True
        return self._done_event.wait(timeout)
