"""Online serving front end for the hierarchical-pipeline runtime.

The batch runtime (paper 1209.3332) takes one ConcreteWorkflow and
drains it; this package turns the same Manager/worker control plane
into a *service*: a continuous stream of tile/pipeline requests flows
through a :class:`~repro.serving.gateway.RequestGateway` that applies
admission control (shed beyond queue-depth / estimated-work caps),
per-tenant weighted fair queueing, and deadline stamping; stages
inherit the request deadline so the Manager's pending queue and every
worker's ready queue run an earliest-deadline-first tier above the
PATS speedup order.  Workers join and drain mid-stream (elastic
membership is a Manager primitive: leases re-queued, push reservations
released atomically).  :mod:`~repro.serving.workload` generates the
open-loop Poisson/Zipf traces both the threaded runtime and the
discrete-event simulator replay.
"""

from .gateway import GatewayConfig, GatewayStats, RequestGateway
from .request import DONE, FAILED, QUEUED, RUNNING, SHED, ServeRequest
from .workload import Arrival, WorkloadConfig, generate_arrivals, zipf_weights

__all__ = [
    "Arrival",
    "DONE",
    "FAILED",
    "GatewayConfig",
    "GatewayStats",
    "QUEUED",
    "RUNNING",
    "RequestGateway",
    "SHED",
    "ServeRequest",
    "WorkloadConfig",
    "generate_arrivals",
    "zipf_weights",
]
