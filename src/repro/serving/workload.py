"""Open-loop synthetic serving workload: Poisson arrivals, Zipf tiles.

Serving-side evaluation needs *offered load the system does not
control*: requests arrive on the clock's schedule whether or not the
cluster keeps up (open-loop), which is what exposes queueing collapse
at saturation — a closed loop would politely slow its offered load and
hide it.  Arrivals are Poisson per tenant (exponential inter-arrival
gaps at each tenant's offered rate) and each request targets a tile
drawn from a Zipf popularity distribution over ``n_tiles`` — hot tiles
dominate, mirroring map-viewer traffic over a whole-slide image where
the current viewport's tiles are requested by many users at once.

The same generator drives the threaded runtime (``benchmarks/serving``
replays the trace against a real Manager) and the discrete-event
simulator (``SimConfig.arrival_rate``), so measured and simulated
latency curves come from identical traces.
"""

from __future__ import annotations

import bisect
import itertools
import random
from dataclasses import dataclass, field
from typing import Mapping, Optional

__all__ = ["WorkloadConfig", "Arrival", "zipf_weights", "generate_arrivals"]


@dataclass(frozen=True)
class Arrival:
    """One open-loop request: at time ``t`` (seconds from stream
    start), ``tenant`` asks for ``tile``; optionally with a relative
    completion deadline."""

    t: float
    tenant: str
    tile: int
    deadline_s: Optional[float] = None


@dataclass
class WorkloadConfig:
    """Knobs of the synthetic request stream.

    ``arrival_rate`` is the offered rate in requests/second *per
    tenant* unless ``tenant_rates`` overrides a tenant explicitly —
    per-tenant rates keep fairness experiments symmetric (every tenant
    offers the same overload; the weighted-fair gateway decides who
    gets through).
    """

    arrival_rate: float = 50.0
    duration_s: float = 1.0
    #: tenant name -> WFQ weight (also the default arrival split).
    tenants: Mapping[str, float] = field(default_factory=lambda: {"t0": 1.0})
    #: optional per-tenant offered rate override (requests/second).
    tenant_rates: Optional[Mapping[str, float]] = None
    zipf_alpha: float = 1.1
    n_tiles: int = 64
    #: relative deadline applied to every request (None = best effort).
    deadline_ms: Optional[float] = None
    seed: int = 0


def zipf_weights(n: int, alpha: float) -> list[float]:
    """Normalized Zipf pmf over ranks ``0..n-1``: p(k) ∝ 1/(k+1)^alpha."""
    raw = [1.0 / float(k + 1) ** alpha for k in range(n)]
    total = sum(raw)
    return [w / total for w in raw]


def _zipf_sampler(n: int, alpha: float):
    cdf = list(itertools.accumulate(zipf_weights(n, alpha)))
    cdf[-1] = 1.0  # guard float drift at the top

    def sample(rng: random.Random) -> int:
        return bisect.bisect_left(cdf, rng.random())

    return sample


def generate_arrivals(cfg: WorkloadConfig) -> list[Arrival]:
    """The full trace, time-sorted.  Deterministic in ``cfg.seed``:
    each tenant's Poisson stream gets its own derived RNG, so adding a
    tenant never perturbs another tenant's arrivals."""
    sample_tile = _zipf_sampler(max(int(cfg.n_tiles), 1), cfg.zipf_alpha)
    deadline_s = (
        cfg.deadline_ms / 1000.0 if cfg.deadline_ms is not None else None
    )
    out: list[Arrival] = []
    for idx, tenant in enumerate(sorted(cfg.tenants)):
        rate = float(
            (cfg.tenant_rates or {}).get(tenant, cfg.arrival_rate)
        )
        if rate <= 0.0:
            continue
        # Independent derived stream per tenant (int seed: tuple
        # seeding is deprecated and hash-unstable across runs).
        rng = random.Random(cfg.seed * 1_000_003 + idx)
        t = 0.0
        while True:
            t += rng.expovariate(rate)
            if t >= cfg.duration_s:
                break
            out.append(
                Arrival(
                    t=t,
                    tenant=tenant,
                    tile=sample_tile(rng),
                    deadline_s=deadline_s,
                )
            )
    out.sort(key=lambda a: a.t)
    return out
