"""Request gateway: admission control + weighted fair queueing.

The gateway is the serving front door in front of the demand-driven
Manager.  It does three things the batch path never needed:

**Admission control.**  An open-loop stream can offer more work than
the cluster clears; without a bound the pending queue grows without
limit and *every* request's latency diverges (queueing collapse).  The
gateway sheds (HTTP-429 analogue) when either the queued-request count
or the estimated queued work (sum of per-request service-time
estimates, learned online from observed completions) exceeds its cap —
so p99 latency for *admitted* requests stays bounded at any offered
load, which is the serving contract worth having.

**Per-tenant weighted fair queueing.**  Start-time fair queueing over
virtual time: each admitted request is stamped ``start = max(vtime,
tenant.last_finish)``, ``finish = start + cost/weight``; dispatch
always takes the tenant whose head-of-line request has the smallest
finish tag and advances ``vtime`` to its start tag.  A bursting tenant
only queues behind its own backlog — it cannot starve a light tenant —
and under sustained overload throughput splits proportionally to the
configured weights.

**Deadline inheritance.**  A request's absolute deadline is stamped
onto every stage instance of its pipeline replica
(``ConcreteWorkflow.instantiate(chunk, deadline=...)``), which is what
the Manager's EDF pending tier and the per-node scheduler's EDF lane
order by.

The gateway keeps at most ``max_inflight`` requests inside the Manager
at once: WFQ can only arbitrate among requests it has *not yet*
released, so the window is what converts a fair queue into fair
throughput (an unbounded release would collapse WFQ to FIFO-at-the-
Manager).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Optional

from .request import DONE, FAILED, QUEUED, RUNNING, SHED, ServeRequest
from ..telemetry.metrics import Histogram
from ..telemetry.tracing import use_context

__all__ = ["GatewayConfig", "GatewayStats", "RequestGateway"]


@dataclass
class GatewayConfig:
    #: queued-request cap: submissions beyond it are shed.
    max_queue: int = 256
    #: estimated-work cap in seconds of queued service time (None = no
    #: work-based admission; the queue-depth cap still applies).
    max_est_work_s: Optional[float] = None
    #: requests concurrently released into the Manager.  Small enough
    #: that WFQ still arbitrates, large enough to keep workers busy.
    max_inflight: int = 8
    #: deadline applied when the caller does not pass one (ms).
    default_deadline_ms: Optional[float] = None
    #: initial per-request service-time estimate (seconds), refined by
    #: an EMA over observed completions.
    initial_cost_s: float = 0.05
    #: EMA smoothing for the service-time estimate.
    cost_ema: float = 0.2
    #: feasibility-aware overload shedding: refuse exactly the
    #: deadline-carrying requests whose deadline fails an EDF
    #: schedulability test against the *measured* service-time tail
    #: (histogram p99, not the EMA mean — overload is a tail
    #: phenomenon): with the earlier-or-equal-deadline backlog plus
    #: in-flight requests ahead of it across ``max_inflight`` release
    #: slots, can this request still finish by its deadline?  Requests
    #: without a deadline are never feasibility-shed.  Mirrored as
    #: ``SimConfig.shed_feasibility``.
    shed_feasibility: bool = False
    #: service-time percentile the feasibility test budgets per request.
    feasibility_pct: float = 0.99
    #: observed completions before the histogram percentile is trusted
    #: (the EMA estimate stands in below this).
    feasibility_min_samples: int = 8


@dataclass
class GatewayStats:
    submitted: int = 0
    admitted: int = 0
    shed: int = 0
    #: subset of ``shed`` refused by the EDF feasibility test (their
    #: deadline was unmeetable against measured queued work).
    shed_infeasible: int = 0
    completed: int = 0
    #: requests that terminated in FAILED (pipeline quarantined).
    failed: int = 0
    #: per-tenant completed counts (fairness accounting).
    tenant_completed: dict[str, int] = field(default_factory=dict)
    tenant_shed: dict[str, int] = field(default_factory=dict)
    tenant_failed: dict[str, int] = field(default_factory=dict)
    #: arrival-to-done latencies of completed requests (seconds).
    latencies: list[float] = field(default_factory=list)
    deadline_misses: int = 0

    def bind(self, registry, prefix: str = "gateway") -> None:
        """Re-home the scalar counters as int-like cells in a shared
        :class:`~repro.telemetry.metrics.MetricsRegistry` (the per-
        tenant dicts and the latency list stay plain — they are not
        monotone scalars).  Existing values seed the cells."""
        for name in (
            "submitted",
            "admitted",
            "shed",
            "shed_infeasible",
            "completed",
            "failed",
            "deadline_misses",
        ):
            cell = registry.counter(f"{prefix}.{name}")
            cell.inc(int(getattr(self, name)))
            setattr(self, name, cell)


class _TenantState:
    __slots__ = ("weight", "queue", "last_finish")

    def __init__(self, weight: float):
        self.weight = max(float(weight), 1e-9)
        self.queue: deque[tuple[float, float, ServeRequest]] = deque()
        self.last_finish = 0.0  # virtual finish tag of the newest entry


class RequestGateway:
    """Front door over a streaming Manager.

    ``manager`` must expose ``cw`` (a live ConcreteWorkflow),
    ``submit_instances``, ``open_stream``/``close_stream`` and a
    ``completion_hook`` slot — i.e. :class:`repro.core.manager.Manager`
    in streaming mode.
    """

    def __init__(
        self,
        manager: Any,
        config: Optional[GatewayConfig] = None,
        tenants: Optional[Mapping[str, float]] = None,
        clock: Callable[[], float] = time.monotonic,
        *,
        registry: Any = None,
        tracer: Any = None,
        recorder: Any = None,
    ):
        self.manager = manager
        self.cfg = config or GatewayConfig()
        self.clock = clock
        self.tracer = tracer          # telemetry.Tracer (optional)
        self.recorder = recorder      # telemetry.FlightRecorder (optional)
        self.stats = GatewayStats()
        if registry is not None:
            self.stats.bind(registry)
        self._lock = threading.RLock()
        self._idle = threading.Event()
        self._idle.set()
        self._tenants: dict[str, _TenantState] = {}
        for name, weight in (tenants or {}).items():
            self._tenants[name] = _TenantState(weight)
        self._vtime = 0.0
        self._queued = 0
        self._inflight = 0
        self._est_queued_work = 0.0
        self._service_est = self.cfg.initial_cost_s
        # Measured service-time distribution (dispatch-to-done): the
        # feasibility test budgets its tail percentile per request.
        self._service_hist = (
            registry.histogram("gateway.service_s")
            if registry is not None
            else Histogram("gateway.service_s")
        )
        self._next_id = 0
        #: terminal stage uid -> its request (completion fan-in).
        self._terminal: dict[int, ServeRequest] = {}
        #: req_id -> request (status lookups, e.g. over the bus).
        self._requests: dict[int, ServeRequest] = {}
        manager.completion_hook = self._on_stage_done
        if hasattr(manager, "failure_hook"):
            manager.failure_hook = self._on_stage_failed
        manager.open_stream()

    # -- ingestion ---------------------------------------------------------

    def add_tenant(self, name: str, weight: float = 1.0) -> None:
        with self._lock:
            self._tenants.setdefault(name, _TenantState(weight))

    def submit(
        self,
        tenant: str,
        chunk: Any,
        deadline_ms: Optional[float] = None,
        cost_s: Optional[float] = None,
    ) -> ServeRequest:
        """Admit-or-shed one request.  Returns the request either way;
        check ``accepted`` — a shed request never runs (429)."""
        now = self.clock()
        with self._lock:
            self.stats.submitted += 1
            ts = self._tenants.get(tenant)
            if ts is None:
                ts = self._tenants[tenant] = _TenantState(1.0)
            cost = float(cost_s) if cost_s is not None else self._service_est
            if deadline_ms is None:
                deadline_ms = self.cfg.default_deadline_ms
            deadline = now + deadline_ms / 1000.0 if deadline_ms else None
            req = ServeRequest(
                req_id=self._next_id,
                tenant=tenant,
                chunk=chunk,
                arrival=now,
                cost=cost,
                deadline=deadline,
            )
            self._next_id += 1
            self._requests[req.req_id] = req
            if self._queued >= self.cfg.max_queue or (
                self.cfg.max_est_work_s is not None
                and self._est_queued_work + cost > self.cfg.max_est_work_s
            ):
                req.state = SHED
                self.stats.shed += 1
                self.stats.tenant_shed[tenant] = (
                    self.stats.tenant_shed.get(tenant, 0) + 1
                )
                return req
            if (
                self.cfg.shed_feasibility
                and deadline is not None
                and not self._feasible_locked(now, deadline, req)
            ):
                req.state = SHED
                self.stats.shed += 1
                self.stats.shed_infeasible += 1
                self.stats.tenant_shed[tenant] = (
                    self.stats.tenant_shed.get(tenant, 0) + 1
                )
                return req
            self.stats.admitted += 1
            if self.tracer is not None:
                # Root the request's trace at admission; the sampling
                # decision made here travels with every downstream hop.
                req.trace = self.tracer.start_trace()
                if req.trace.sampled:
                    self.tracer.record_span(
                        "gateway:admit",
                        ctx=self.tracer.child(req.trace),
                        parent=req.trace.span_id,
                        cat="request",
                        tid="gateway",
                        args={"req_id": req.req_id, "tenant": tenant},
                    )
            self._idle.clear()
            # SFQ tags: charge by estimated cost over tenant weight.
            start = max(self._vtime, ts.last_finish)
            finish = start + cost / ts.weight
            ts.last_finish = finish
            ts.queue.append((finish, start, req))
            self._queued += 1
            self._est_queued_work += cost
            self._dispatch_locked()
            return req

    # -- feasibility-aware overload shedding -------------------------------

    def _feasible_locked(
        self, now: float, deadline: float, req: ServeRequest
    ) -> bool:
        """EDF schedulability test for one candidate request: budget the
        measured per-request service tail for every queued request with
        an earlier-or-equal deadline (those run first under EDF), every
        in-flight request (already occupying release slots), and the
        candidate itself, spread across ``max_inflight`` parallel
        slots.  If even that optimistic pipeline cannot land the
        candidate by its deadline, admitting it only converts a certain
        miss into wasted cluster work — shed it instead."""
        if self._service_hist.count >= self.cfg.feasibility_min_samples:
            service = self._service_hist.percentile(self.cfg.feasibility_pct)
        else:
            service = self._service_est
        if not service or service <= 0.0:
            return True
        ahead = self._inflight
        for ts in self._tenants.values():
            for _, _, queued in ts.queue:
                if queued.deadline is None or queued.deadline <= deadline:
                    ahead += 1
        slots = max(self.cfg.max_inflight, 1)
        est_done = now + service * (ahead + 1) / slots
        if est_done <= deadline:
            return True
        if self.recorder is not None:
            self.recorder.note(
                "feasibility_shed",
                req_id=req.req_id,
                tenant=req.tenant,
                deadline_in_s=round(deadline - now, 4),
                service_pct_s=round(service, 4),
                backlog=ahead,
                est_done_in_s=round(est_done - now, 4),
            )
        return False

    # -- WFQ dispatch ------------------------------------------------------

    def _dispatch_locked(self) -> None:
        while self._inflight < self.cfg.max_inflight:
            best: Optional[_TenantState] = None
            for ts in self._tenants.values():
                if ts.queue and (
                    best is None or ts.queue[0][0] < best.queue[0][0]
                ):
                    best = ts
            if best is None:
                return
            finish, start, req = best.queue.popleft()
            self._vtime = max(self._vtime, start)
            self._queued -= 1
            self._est_queued_work = max(
                0.0, self._est_queued_work - req.cost
            )
            self._inflight += 1
            req.state = RUNNING
            req.t_dispatch = self.clock()
            sis = self.manager.cw.instantiate(req.chunk, deadline=req.deadline)
            uids = {si.uid for si in sis}
            terminals = [
                si for si in sis if not (si.dependents & uids)
            ] or sis[-1:]
            req.stage_uids = tuple(sorted(uids))
            req.remaining = len(terminals)
            for si in terminals:
                self._terminal[si.uid] = req
            if req.trace is not None and req.trace.sampled:
                # The Manager captures this context per queued stage and
                # re-installs it around each lease — the whole pipeline
                # replica traces back to this request.
                with use_context(req.trace):
                    self.manager.submit_instances(sis)
            else:
                self.manager.submit_instances(sis)

    # -- completion --------------------------------------------------------

    def _on_stage_done(self, uid: int) -> None:
        with self._lock:
            req = self._terminal.pop(uid, None)
            if req is None:
                return
            req.remaining -= 1
            if req.remaining > 0:
                return
            req.state = DONE
            req.t_done = self.clock()
            self._inflight -= 1
            self.stats.completed += 1
            self.stats.tenant_completed[req.tenant] = (
                self.stats.tenant_completed.get(req.tenant, 0) + 1
            )
            lat = req.latency
            if lat is not None:
                self.stats.latencies.append(lat)
            missed = req.deadline is not None and req.t_done > req.deadline
            if missed:
                self.stats.deadline_misses += 1
            if self.tracer is not None and req.trace is not None and lat is not None:
                # The root span: arrival-to-done, everything else in the
                # trace (leases, ops, pulls, pushes) nests under it.
                self.tracer.record_span(
                    "request",
                    ctx=req.trace,
                    cat="request",
                    ts=time.time() - lat,
                    dur=lat,
                    tid="gateway",
                    args={
                        "req_id": req.req_id,
                        "tenant": req.tenant,
                        "deadline_miss": missed,
                    },
                )
            if missed and self.recorder is not None:
                self.recorder.dump(
                    "deadline_miss",
                    detail={
                        "req_id": req.req_id,
                        "tenant": req.tenant,
                        "latency": lat,
                        "tardiness": req.tardiness,
                    },
                )
            # Online service-time estimate: dispatch-to-done, which is
            # what one admitted request actually costs the cluster
            # (queueing excluded — admission should not double-count
            # its own backlog).
            if req.t_dispatch is not None:
                obs = max(req.t_done - req.t_dispatch, 1e-6)
                a = self.cfg.cost_ema
                self._service_est = (1 - a) * self._service_est + a * obs
                self._service_hist.observe(obs)
            self._dispatch_locked()
            if self._queued == 0 and self._inflight == 0:
                self._idle.set()
        req._done_event.set()

    def _on_stage_failed(self, uid: int, error: str) -> None:
        """Manager ``failure_hook``: a stage of ours was quarantined.

        The Manager cascades quarantine over dependents, so the
        request's terminal stage(s) always land here.  The first
        terminal failure decides the request: it goes FAILED, its
        remaining terminal fan-in entries are cleared, and the tenant
        gets a verdict (``error``) instead of a hung request.
        """
        with self._lock:
            req = self._terminal.pop(uid, None)
            if req is None or req.state in (DONE, FAILED):
                return
            # Drop the request's other terminal entries — the verdict
            # is already decided and later hooks must not double-count.
            for other in [u for u, r in self._terminal.items() if r is req]:
                del self._terminal[other]
            req.remaining = 0
            req.state = FAILED
            req.error = error
            req.t_done = self.clock()
            self._inflight -= 1
            self.stats.failed += 1
            self.stats.tenant_failed[req.tenant] = (
                self.stats.tenant_failed.get(req.tenant, 0) + 1
            )
            if self.tracer is not None and req.trace is not None:
                lat = req.latency or 0.0
                self.tracer.record_span(
                    "request",
                    ctx=req.trace,
                    cat="request",
                    ts=time.time() - lat,
                    dur=lat,
                    tid="gateway",
                    args={
                        "req_id": req.req_id,
                        "tenant": req.tenant,
                        "failed": True,
                        "error": error,
                    },
                )
            self._dispatch_locked()
            if self._queued == 0 and self._inflight == 0:
                self._idle.set()
        req._done_event.set()

    # -- lifecycle ---------------------------------------------------------

    def drain(self, timeout: float = 60.0) -> bool:
        """Wait until everything admitted so far has completed."""
        return self._idle.wait(timeout)

    def close(self, timeout: float = 60.0) -> bool:
        """Drain, then close the Manager's stream."""
        ok = self.drain(timeout)
        return self.manager.close_stream(timeout) and ok

    # -- introspection -----------------------------------------------------

    def request(self, req_id: int) -> Optional[ServeRequest]:
        with self._lock:
            return self._requests.get(req_id)

    def queue_depth(self) -> int:
        with self._lock:
            return self._queued

    def inflight(self) -> int:
        with self._lock:
            return self._inflight

    def service_estimate(self) -> float:
        with self._lock:
            return self._service_est
