"""Staging configuration shared by Worker, Manager, and benchmarks."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .policy import PlacementPolicy
from .store import RegionStore
from .tiers import DiskTier, GlobalTier, HostTier

__all__ = ["StagingConfig"]


@dataclass
class StagingConfig:
    """How one worker builds its storage hierarchy.

    The host tier always exists (it replaces the worker's ad-hoc output
    dict); disk and global tiers are optional.  One ``global_tier``
    instance shared across StagingConfigs models the cluster's shared
    store, letting StagingAgents prefetch remote outputs.
    """

    host_budget_bytes: Optional[int] = None   # None = unbounded RAM
    disk_dir: Optional[str] = None            # spill directory; None = off
    disk_budget_bytes: Optional[int] = None
    global_tier: Optional[GlobalTier] = None  # shared cluster store
    prefetch: bool = True                     # run the StagingAgent thread
    watermark: float = 0.9                    # host-tier demotion trigger
    policy: PlacementPolicy = field(default_factory=PlacementPolicy)

    def build_store(self, registry=None) -> RegionStore:
        tiers = [HostTier(self.host_budget_bytes)]
        if self.disk_dir is not None:
            tiers.append(DiskTier(self.disk_dir, self.disk_budget_bytes))
        if self.global_tier is not None:
            tiers.append(self.global_tier)
        return RegionStore(tiers, registry=registry)

    @classmethod
    def from_calibration(
        cls,
        node=None,
        *,
        window: int = 15,
        stage_output_mb: float = 48.0,
        ram_headroom: float = 0.5,
        disk_headroom: float = 0.8,
        disk_dir: Optional[str] = None,
        **kwargs,
    ) -> "StagingConfig":
        """Derive tier budgets from a calibrated node profile.

        The host tier gets ``ram_headroom`` of the node's RAM (the rest
        is application/OS working memory), but never less than the live
        working set the simulator's staging model implies — ``window``
        in-flight leases, each holding one input and one output region
        of ``stage_output_mb`` — so soft budgets stay soft (pins would
        otherwise defeat every byte of the budget).  The disk tier gets
        ``disk_headroom`` of the node's scratch space when a spill
        directory is provided.
        """
        from ..core import calibration as cal  # runtime import: no cycle

        node = node or cal.KEENELAND_NODE
        stage_bytes = int(stage_output_mb * 2**20)
        working_set = 2 * max(window, 1) * stage_bytes
        host_budget = max(
            int(node.host_ram_gb * 2**30 * ram_headroom), working_set
        )
        disk_budget = (
            int(node.scratch_disk_gb * 2**30 * disk_headroom)
            if disk_dir is not None
            else None
        )
        return cls(
            host_budget_bytes=host_budget,
            disk_dir=disk_dir,
            disk_budget_bytes=disk_budget,
            **kwargs,
        )
