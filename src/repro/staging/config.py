"""Staging configuration shared by Worker, Manager, and benchmarks."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .policy import PlacementPolicy
from .store import RegionStore
from .tiers import DiskTier, GlobalTier, HostTier

__all__ = ["StagingConfig"]


@dataclass
class StagingConfig:
    """How one worker builds its storage hierarchy.

    The host tier always exists (it replaces the worker's ad-hoc output
    dict); disk and global tiers are optional.  One ``global_tier``
    instance shared across StagingConfigs models the cluster's shared
    store, letting StagingAgents prefetch remote outputs.
    """

    host_budget_bytes: Optional[int] = None   # None = unbounded RAM
    disk_dir: Optional[str] = None            # spill directory; None = off
    disk_budget_bytes: Optional[int] = None
    global_tier: Optional[GlobalTier] = None  # shared cluster store
    prefetch: bool = True                     # run the StagingAgent thread
    watermark: float = 0.9                    # host-tier demotion trigger
    policy: PlacementPolicy = field(default_factory=PlacementPolicy)

    def build_store(self) -> RegionStore:
        tiers = [HostTier(self.host_budget_bytes)]
        if self.disk_dir is not None:
            tiers.append(DiskTier(self.disk_dir, self.disk_budget_bytes))
        if self.global_tier is not None:
            tiers.append(self.global_tier)
        return RegionStore(tiers)
