"""Cluster-wide placement directory: which worker holds which region.

The Manager's view of the storage hierarchy.  Workers (or the Manager
on their behalf) record region placements as stages complete and
evictions happen; the dispatch loop then asks "who already holds the
inputs of this stage instance?" and leases accordingly — converting the
per-node data-locality of ``core/scheduling.py`` into *cluster-level*
locality-aware lease placement.

The directory is deliberately metadata-only (key -> {worker: bytes});
it never touches payloads, so the same class serves the threaded
runtime, the discrete-event simulator, and — behind a distributed
transport — a real multi-node deployment (ROADMAP open item).
"""

from __future__ import annotations

import threading
from typing import Any, Iterable, Optional

from .tiers import RegionKey

__all__ = ["PlacementDirectory"]


class PlacementDirectory:
    """Thread-safe region -> {worker_id: nbytes} map."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._placement: dict[RegionKey, dict[int, int]] = {}
        # Worker-to-worker data plane: each worker's bus address, so a
        # holder lookup can be answered with a dialable peer instead of
        # relaying the region bytes through the coordinator.
        self._addresses: dict[int, Any] = {}
        # Network topology identity: worker -> rack (leaf switch).  A
        # replica on a same-rack sibling is one intra-rack hop away —
        # no oversubscribed uplink on the path — so placement scoring
        # can weight it above a cross-rack replica (rack_affinity).
        self._racks: dict[int, Any] = {}
        self.records = 0
        self.evictions = 0

    # -- updates -----------------------------------------------------------

    def set_address(self, worker_id: int, address: Any) -> None:
        """Record worker ``worker_id``'s bus address (peer-dial target)."""
        with self._lock:
            self._addresses[int(worker_id)] = address

    def set_rack(self, worker_id: int, rack: Any) -> None:
        """Record worker ``worker_id``'s rack (None = no topology)."""
        with self._lock:
            if rack is None:
                self._racks.pop(int(worker_id), None)
            else:
                self._racks[int(worker_id)] = rack

    def rack_of(self, worker_id: int) -> Any:
        with self._lock:
            return self._racks.get(worker_id)

    def racks(self) -> dict[int, Any]:
        with self._lock:
            return dict(self._racks)

    def address_of(self, worker_id: int) -> Any:
        with self._lock:
            return self._addresses.get(worker_id)

    def addresses(self) -> dict[int, Any]:
        with self._lock:
            return dict(self._addresses)

    def record(self, worker_id: int, key: RegionKey, nbytes: int) -> None:
        """Worker ``worker_id`` now holds ``key`` (``nbytes`` big)."""
        with self._lock:
            self._placement.setdefault(key, {})[worker_id] = nbytes
            self.records += 1

    def evict(self, worker_id: int, key: RegionKey) -> None:
        """Worker dropped its replica of ``key``."""
        with self._lock:
            holders = self._placement.get(key)
            if holders and holders.pop(worker_id, None) is not None:
                self.evictions += 1
                if not holders:
                    del self._placement[key]

    def drop_worker(self, worker_id: int) -> None:
        """Worker left/died: all of its replicas (and address) are gone."""
        with self._lock:
            self._addresses.pop(worker_id, None)
            self._racks.pop(worker_id, None)
            for key in list(self._placement):
                self.evict(worker_id, key)

    # -- queries -----------------------------------------------------------

    def holders(self, key: RegionKey) -> dict[int, int]:
        with self._lock:
            return dict(self._placement.get(key, {}))

    def replicated_elsewhere(self, worker_id: int, key: RegionKey) -> bool:
        """True when another worker also holds ``key`` — dropping the
        local replica then loses no data (replication-aware eviction)."""
        with self._lock:
            holders = self._placement.get(key)
            if not holders:
                return False
            return any(w != worker_id for w in holders)

    def bytes_on(self, worker_id: int, keys: Iterable[RegionKey]) -> int:
        """Bytes of ``keys`` already resident on ``worker_id``."""
        with self._lock:
            return sum(
                self._placement.get(k, {}).get(worker_id, 0) for k in keys
            )

    def total_bytes(self, keys: Iterable[RegionKey]) -> int:
        """Bytes of ``keys`` recorded anywhere (max replica per key)."""
        with self._lock:
            total = 0
            for k in keys:
                holders = self._placement.get(k)
                if holders:
                    total += max(holders.values())
            return total

    def local_fraction(
        self, worker_id: int, keys: Iterable[RegionKey]
    ) -> float:
        """Fraction of the recorded input bytes resident on ``worker_id``."""
        keys = list(keys)
        with self._lock:
            total = self.total_bytes(keys)
            if total <= 0:
                return 0.0
            return self.bytes_on(worker_id, keys) / total

    def rack_fraction(
        self, worker_id: int, keys: Iterable[RegionKey]
    ) -> float:
        """Fraction of the recorded input bytes held by OTHER workers
        in ``worker_id``'s rack (per key, the largest same-rack
        replica counts — never more than the key's own share)."""
        keys = list(keys)
        with self._lock:
            rack = self._racks.get(worker_id)
            if rack is None:
                return 0.0
            total = self.total_bytes(keys)
            if total <= 0:
                return 0.0
            near = 0
            for k in keys:
                holders = self._placement.get(k, {})
                near += max(
                    (
                        n
                        for w, n in holders.items()
                        if w != worker_id and self._racks.get(w) == rack
                    ),
                    default=0,
                )
            return min(near / total, 1.0)

    def placement_score(
        self,
        worker_id: int,
        keys: Iterable[RegionKey],
        rack_affinity: float = 0.0,
    ) -> float:
        """Locality score of leasing work over ``keys`` to ``worker_id``:
        the local byte fraction, plus a rack-locality bonus — bytes a
        same-rack sibling holds count at ``rack_affinity`` weight,
        because pulling them never crosses an oversubscribed uplink."""
        keys = list(keys)
        score = self.local_fraction(worker_id, keys)
        if rack_affinity > 0.0:
            score += rack_affinity * self.rack_fraction(worker_id, keys)
        return score

    def best_worker(
        self, keys: Iterable[RegionKey]
    ) -> Optional[tuple[int, float]]:
        """Worker holding the largest fraction of ``keys``' bytes.

        Returns ``(worker_id, fraction)`` or None when nothing about
        these keys has been recorded yet.
        """
        keys = list(keys)
        with self._lock:
            per_worker: dict[int, int] = {}
            for k in keys:
                for w, n in self._placement.get(k, {}).items():
                    per_worker[w] = per_worker.get(w, 0) + n
            if not per_worker:
                return None
            total = self.total_bytes(keys)
            if total <= 0:
                return None
            w = max(per_worker, key=lambda x: (per_worker[x], -x))
            return w, per_worker[w] / total

    def __len__(self) -> int:
        with self._lock:
            return len(self._placement)
