"""Locality-aware lease placement policy (cluster-level DL).

Mirrors the within-node data-locality rule of ``core/scheduling.py`` at
the Manager level.  There, a resident dependent wins over the best
queued candidate iff ``S_d >= S_q * (1 - transferImpact)``; here, a
pending stage instance is diverted from demand-driven (FIFO) order to a
worker iff the *locality gain* — the extra fraction of its input bytes
already on that worker versus the FIFO head — exceeds the configured
``transfer_impact`` threshold.  With the default threshold of 0 any
positive gain diverts; a deployment whose interconnect is fast relative
to recompute can raise it toward 1 to recover pure demand-driven order.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, Optional, Sequence

from .directory import PlacementDirectory
from .tiers import RegionKey

__all__ = ["PlacementPolicy", "select_lease"]


@dataclass(frozen=True)
class PlacementPolicy:
    """Knobs of cluster-level locality-aware lease placement."""

    locality: bool = True
    # Minimum locality-fraction gain over the FIFO head required to
    # divert from demand-driven order (0 = always prefer locality).
    transfer_impact: float = 0.0
    # Leave a remote-affine stage pending for its home worker when that
    # worker still has window slack (second pass is work-conserving).
    defer_remote: bool = True
    # Cap on how many pending instances to score per dispatch decision.
    scan_limit: int = 64
    # Rack-locality bonus: input bytes held by a same-rack sibling
    # (PlacementDirectory.set_rack identity) count at this weight on
    # top of the worker-local fraction — a same-rack pull crosses the
    # leaf switch only, never an oversubscribed uplink, so on a
    # fat-tree fabric it is nearly as good as local.  0 keeps the
    # rack-blind scoring.
    rack_affinity: float = 0.0
    # Replication-aware host-tier eviction: under budget pressure a
    # worker sheds regions the PlacementDirectory shows replicated on
    # another worker before any sole copy (the Manager wires each
    # worker's host tier to ``directory.replicated_elsewhere``).
    replication_aware_eviction: bool = True


def select_lease(
    pending: Sequence,
    worker_id: int,
    directory: PlacementDirectory,
    input_keys: Callable[[object], Iterable[RegionKey]],
    policy: PlacementPolicy,
    *,
    workers_with_slack: Optional[set[int]] = None,
    allow_defer: bool = True,
) -> Optional[int]:
    """Index into ``pending`` of the instance to lease to ``worker_id``.

    Returns None iff every scanned candidate is deferred to another
    worker that holds its data and still has window slack (the caller
    must then run a second, non-deferring pass for work conservation).
    """
    if not pending:
        return None
    if not policy.locality:
        return 0
    limit = min(len(pending), max(policy.scan_limit, 1))
    best_i, best_f = 0, -1.0
    head_f = 0.0
    for i in range(limit):
        keys = list(input_keys(pending[i]))
        f = (
            directory.placement_score(
                worker_id, keys, policy.rack_affinity
            )
            if keys
            else 0.0
        )
        if i == 0:
            head_f = f
        if f > best_f:
            best_i, best_f = i, f
    if best_f > head_f and best_f - head_f > policy.transfer_impact:
        return best_i
    # No candidate is better-placed here than the FIFO head.  If the
    # head's data lives on another worker that can still take it, defer.
    if (
        allow_defer
        and policy.defer_remote
        and workers_with_slack is not None
    ):
        for i in range(limit):
            keys = list(input_keys(pending[i]))
            if not keys:
                return i  # fresh work: no affinity anywhere
            best = directory.best_worker(keys)
            if best is None or best[1] <= 0.0:
                return i
            home, _ = best
            if home == worker_id or home not in workers_with_slack:
                return i
        return None  # everything scanned belongs to someone else
    return 0  # gain below threshold: demand-driven order wins
