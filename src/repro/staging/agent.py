"""Per-worker staging agent: async promote/demote + batched prefetch.

The paper overlaps data movement with computation (§IV-D, upload /
process / download pipeline).  The StagingAgent generalizes that from
one accelerator lane to the whole storage hierarchy of a worker:

* **prefetch** — the worker enqueues the input keys of stage instances
  it has *leased but not started*; the agent pulls any that are missing
  from the fetch source (global tier / remote worker) into the host
  tier on a background thread, so lanes find them RAM-resident;
* **batched pulls** — queued keys are coalesced and fetched through
  ``fetch_batch`` as one transport round-trip (mirroring micro-batched
  dispatch: amortize the per-call latency over the batch); per-key
  ``fetch`` remains the fallback when no batch source is wired;
* **promote** — a requested key sitting in a slow tier (disk) is moved
  up ahead of use;
* **demote** — when the host tier crosses its high-water mark, LRU
  regions spill one level down off the critical path, so lane threads
  rarely block on synchronous eviction.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Optional, Sequence

from .store import RegionStore
from .tiers import RegionKey, sizeof

__all__ = ["StagingAgent"]

FetchFn = Callable[[RegionKey], Any]
#: Batched pull: ordered keys in, same-length ordered values out
#: (None per miss); returning None means "no batch source, fall back".
FetchBatchFn = Callable[[Sequence[RegionKey]], Optional[Sequence[Any]]]


class StagingAgent:
    def __init__(
        self,
        store: RegionStore,
        *,
        worker_id: int = 0,
        fetch: Optional[FetchFn] = None,
        fetch_batch: Optional[FetchBatchFn] = None,
        max_batch: int = 16,
        on_staged: Optional[Callable[[RegionKey, int], None]] = None,
        watermark: float = 0.9,
        interval: float = 0.002,
    ) -> None:
        self.store = store
        self.worker_id = worker_id
        self.fetch = fetch
        self.fetch_batch = fetch_batch
        self.max_batch = max(int(max_batch), 1)
        self.on_staged = on_staged  # e.g. PlacementDirectory.record
        self.watermark = watermark
        # Idle wake-up only matters when some tier can actually demote;
        # with all tiers unbounded, poll rarely (requests still wake the
        # thread immediately via the queue).
        bounded = any(t.budget_bytes is not None for t in store.tiers)
        self.interval = interval if bounded else max(interval, 0.25)
        self._requests: "queue.Queue[Optional[RegionKey]]" = queue.Queue()
        self._inflight: set[RegionKey] = set()
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        # Counters read by benchmarks / tests.
        self.prefetched = 0
        self.prefetched_bytes = 0
        self.already_resident = 0
        self.fetch_misses = 0
        self.demote_moves = 0
        self.fetch_calls = 0        # transport round-trips actually paid
        self.batched_keys = 0       # keys that rode a coalesced pull
        self.fetch_errors = 0       # pulls that raised (bus timeout/drop)

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop = False
        self._thread = threading.Thread(
            target=self._loop, daemon=True,
            name=f"staging-agent-{self.worker_id}",
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop = True
        self._requests.put(None)
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    # -- worker API --------------------------------------------------------

    def request_prefetch(self, keys) -> None:
        """Ask for ``keys`` to be host-resident soon (idempotent)."""
        with self._lock:
            for key in keys:
                if key in self._inflight:
                    continue
                self._inflight.add(key)
                self._requests.put(key)

    def stage_now(self, key: RegionKey) -> bool:
        """Synchronous fallback: a lane needs ``key`` immediately."""
        return self._stage(key)

    # -- internals ---------------------------------------------------------

    def _loop(self) -> None:
        while not self._stop:
            try:
                key = self._requests.get(timeout=self.interval)
            except queue.Empty:
                self.demote_moves += self.store.demote_excess(self.watermark)
                continue
            if key is None:
                return
            # Coalesce whatever else is already queued into one batch:
            # one transport round-trip serves every key waiting now.
            keys = [key]
            while len(keys) < self.max_batch:
                try:
                    nxt = self._requests.get_nowait()
                except queue.Empty:
                    break
                if nxt is None:
                    self._stop = True
                    break
                keys.append(nxt)
            try:
                self._stage_batch(keys)
            except Exception:  # noqa: BLE001 - transport hiccup, not fatal
                # A fetch source over a bus raises on timeouts/restarts
                # (e.g. Manager failover).  The prefetch thread must
                # survive: the keys count as misses and the lanes'
                # synchronous re-pull path remains the backstop.
                self.fetch_errors += 1
                self.fetch_misses += len(keys)
            finally:
                with self._lock:
                    for k in keys:
                        self._inflight.discard(k)

    def _local_hit(self, key: RegionKey) -> bool:
        """Serve ``key`` from a local tier if present (promote slow hits)."""
        where = self.store.where(key)
        if where is None:
            return False
        if where == self.store.tiers[0].name:
            self.already_resident += 1
        else:
            # Promote from a slow tier ahead of use.
            self.store.get(key, promote=True)
            self.prefetched += 1
        # on_staged fires on *every* success path: a region found in
        # a lower tier (e.g. the shared global store) is just as
        # newly-available to the consumer as a fetched one.
        if self.on_staged is not None:
            self.on_staged(key, 0)
        return True

    def _land(self, key: RegionKey, value: Any) -> None:
        nbytes = sizeof(value)
        self.store.put(key, value, tier=self.store.tiers[0].name, nbytes=nbytes)
        self.prefetched += 1
        self.prefetched_bytes += nbytes
        if self.on_staged is not None:
            self.on_staged(key, nbytes)

    def _stage_batch(self, keys: list[RegionKey]) -> None:
        missing = [k for k in keys if not self._local_hit(k)]
        if not missing:
            return
        values = None
        if self.fetch_batch is not None:
            values = self.fetch_batch(missing)
            if values is not None:
                self.fetch_calls += 1
                self.batched_keys += len(missing)
        if values is not None:
            for k, v in zip(missing, values):
                if v is None:
                    self.fetch_misses += 1
                else:
                    self._land(k, v)
            return
        for k in missing:  # no batch source wired: per-key round-trips
            self._fetch_one(k)

    def _stage(self, key: RegionKey) -> bool:
        if self._local_hit(key):
            return True
        return self._fetch_one(key)

    def _fetch_one(self, key: RegionKey) -> bool:
        if self.fetch is None:
            self.fetch_misses += 1
            return False
        self.fetch_calls += 1
        value = self.fetch(key)
        if value is None:
            self.fetch_misses += 1
            return False
        self._land(key, value)
        return True

    def stats(self) -> dict[str, int]:
        return {
            "prefetched": self.prefetched,
            "prefetched_bytes": self.prefetched_bytes,
            "already_resident": self.already_resident,
            "fetch_misses": self.fetch_misses,
            "demote_moves": self.demote_moves,
            "fetch_calls": self.fetch_calls,
            "batched_keys": self.batched_keys,
            "fetch_errors": self.fetch_errors,
        }
