"""Per-worker staging agent: async promote/demote + batched prefetch.

The paper overlaps data movement with computation (§IV-D, upload /
process / download pipeline).  The StagingAgent generalizes that from
one accelerator lane to the whole storage hierarchy of a worker:

* **prefetch** — the worker enqueues the input keys of stage instances
  it has *leased but not started*; the agent pulls any that are missing
  from the fetch source (global tier / remote worker) into the host
  tier on a background thread, so lanes find them RAM-resident;
* **batched pulls** — queued keys are coalesced and fetched through
  ``fetch_batch`` as one transport round-trip (mirroring micro-batched
  dispatch: amortize the per-call latency over the batch); per-key
  ``fetch`` remains the fallback when no batch source is wired;
* **direct dial (coordinator bypass)** — with ``resolve``/``dial``
  wired, missing keys are resolved to sibling holders through a cached
  directory lookup and the region bytes are pulled worker-to-worker;
  the Manager relay (``fetch``/``fetch_batch``) remains the fallback
  when the holder is unknown, stale, or dead.  The holder cache is
  invalidation-correct: ``invalidate_holder`` (driven by the Manager's
  ``region_drop`` broadcast) guarantees a direct dial never targets a
  holder that spilled the region without at worst one wasted dial;
* **expected pushes** — the Manager may predict that a sibling will
  *push* a key here (predictive push of sink outputs); ``expect_push``
  defers the pull for a grace period so the push and the pull don't
  race the same bytes across the wire, with the pull re-arming as the
  backstop when the push never lands;
* **promote** — a requested key sitting in a slow tier (disk) is moved
  up ahead of use;
* **demote** — when the host tier crosses its high-water mark, LRU
  regions spill one level down off the critical path, so lane threads
  rarely block on synchronous eviction.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Optional, Sequence

from .store import RegionStore
from .tiers import RegionKey, sizeof

__all__ = ["StagingAgent"]

FetchFn = Callable[[RegionKey], Any]
#: Batched pull: ordered keys in, same-length ordered values out
#: (None per miss); returning None means "no batch source, fall back".
FetchBatchFn = Callable[[Sequence[RegionKey]], Optional[Sequence[Any]]]
#: Holder lookup for the direct data plane: ordered keys in, same-length
#: ``(worker_id, bus_address)`` (or None per unknown key) out; returning
#: None means the lookup itself failed (coordinator unreachable).
ResolveFn = Callable[[Sequence[RegionKey]], Optional[Sequence[Any]]]
#: Peer dial: ``dial(holder, keys)`` pulls the keys straight from the
#: sibling ``holder = (worker_id, address)``; None = holder unreachable.
DialFn = Callable[[Any, Sequence[RegionKey]], Optional[Sequence[Any]]]


class StagingAgent:
    def __init__(
        self,
        store: RegionStore,
        *,
        worker_id: int = 0,
        fetch: Optional[FetchFn] = None,
        fetch_batch: Optional[FetchBatchFn] = None,
        resolve: Optional[ResolveFn] = None,
        dial: Optional[DialFn] = None,
        max_batch: int = 16,
        on_staged: Optional[Callable[[RegionKey, int], None]] = None,
        watermark: float = 0.9,
        interval: float = 0.002,
        push_grace: float = 0.25,
        registry=None,
    ) -> None:
        from ..telemetry.metrics import MetricsRegistry

        self.store = store
        self.worker_id = worker_id
        self.fetch = fetch
        self.fetch_batch = fetch_batch
        # Coordinator-bypass data plane (wired by a transport
        # WorkerClient): resolve holders, dial the sibling directly.
        self.resolve = resolve
        self.dial = dial
        self.push_grace = push_grace
        self.max_batch = max(int(max_batch), 1)
        self.on_staged = on_staged  # e.g. PlacementDirectory.record
        self.watermark = watermark
        # Idle wake-up only matters when some tier can actually demote;
        # with all tiers unbounded, poll rarely (requests still wake the
        # thread immediately via the queue).
        bounded = any(t.budget_bytes is not None for t in store.tiers)
        self.interval = interval if bounded else max(interval, 0.25)
        self._requests: "queue.Queue[Optional[RegionKey]]" = queue.Queue()
        self._inflight: set[RegionKey] = set()
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        # Directory cache for the direct path: key -> (worker_id, addr).
        # Entries die on region_drop/eviction notifies (invalidate_*) or
        # when a dial comes back empty/dead — never silently trusted.
        self._holders: dict[RegionKey, tuple] = {}
        # Keys a sibling was predicted to push here: key -> deadline.
        # The pull is deferred until the deadline so push and pull don't
        # move the same bytes twice; overdue keys re-enter the queue.
        self._deferred: dict[RegionKey, float] = {}
        # Counters read by benchmarks / tests — int-like cells in the
        # shared metrics registry (`stats()` stays the thin int view).
        self.registry = registry or MetricsRegistry()
        c = lambda name: self.registry.counter(f"staging.{name}")  # noqa: E731
        self.prefetched = c("prefetched")
        self.prefetched_bytes = c("prefetched_bytes")
        self.already_resident = c("already_resident")
        self.fetch_misses = c("fetch_misses")
        self.demote_moves = c("demote_moves")
        self.fetch_calls = c("fetch_calls")      # round-trips actually paid
        self.batched_keys = c("batched_keys")    # keys on a coalesced pull
        self.fetch_errors = c("fetch_errors")    # pulls that raised
        self.direct_keys = c("direct_keys")      # keys served worker-to-worker
        self.direct_bytes = c("direct_bytes")
        self.direct_misses = c("direct_misses")  # stale holder: region gone
        self.relay_keys = c("relay_keys")        # fell back to the Manager
        self.relay_bytes = c("relay_bytes")
        self.holder_invalidations = c("holder_invalidations")
        self.pushes_expected = c("pushes_expected")
        self.pushes_landed = c("pushes_landed")  # pushes arrived in time

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop = False
        self._thread = threading.Thread(
            target=self._loop, daemon=True,
            name=f"staging-agent-{self.worker_id}",
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop = True
        self._requests.put(None)
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    # -- worker API --------------------------------------------------------

    def request_prefetch(self, keys) -> None:
        """Ask for ``keys`` to be host-resident soon (idempotent)."""
        with self._lock:
            for key in keys:
                if key in self._inflight:
                    continue
                self._inflight.add(key)
                self._requests.put(key)

    def stage_now(self, key: RegionKey) -> bool:
        """Synchronous fallback: a lane needs ``key`` immediately."""
        return self._stage(key)

    def expect_push(self, keys) -> None:
        """A sibling is predicted to push ``keys`` here: defer their
        pull for ``push_grace`` seconds so the push and the prefetch
        don't race the same bytes.  Overdue keys pull normally — the
        grace period bounds the stall when a push is lost."""
        deadline = time.monotonic() + self.push_grace
        n = 0
        with self._lock:
            for key in keys:
                if key in self._inflight:
                    continue
                self._inflight.add(key)
                self._deferred[key] = deadline
                n += 1
        self.pushes_expected += n

    def invalidate_holder(
        self, key: RegionKey, worker_id: Optional[int] = None
    ) -> None:
        """Region ``key`` left ``worker_id``'s tiers (drop/eviction
        notify): forget the cached holder so a direct dial never fetches
        from a sibling that spilled the region."""
        with self._lock:
            h = self._holders.get(key)
            if h is not None and (worker_id is None or h[0] == worker_id):
                del self._holders[key]
                self.holder_invalidations += 1

    def invalidate_worker(self, worker_id: int) -> None:
        """Worker died/left: every cached holder entry naming it is gone."""
        with self._lock:
            stale = [
                k for k, h in self._holders.items() if h[0] == worker_id
            ]
            for k in stale:
                del self._holders[k]
            self.holder_invalidations += len(stale)

    # -- internals ---------------------------------------------------------

    def _loop(self) -> None:
        while not self._stop:
            # With pushes pending, poll fast enough that an overdue
            # (lost) push degrades to a pull within ~one grace period.
            timeout = self.interval
            if self._deferred:
                timeout = min(timeout, max(self.push_grace / 4.0, 0.01))
            try:
                key = self._requests.get(timeout=timeout)
            except queue.Empty:
                self._check_deferred()
                self.demote_moves += self.store.demote_excess(self.watermark)
                continue
            if key is None:
                return
            # Coalesce whatever else is already queued into one batch:
            # one transport round-trip serves every key waiting now.
            keys = [key]
            while len(keys) < self.max_batch:
                try:
                    nxt = self._requests.get_nowait()
                except queue.Empty:
                    break
                if nxt is None:
                    self._stop = True
                    break
                keys.append(nxt)
            try:
                self._stage_batch(keys)
            except Exception:  # noqa: BLE001 - transport hiccup, not fatal
                # A fetch source over a bus raises on timeouts/restarts
                # (e.g. Manager failover).  The prefetch thread must
                # survive: the keys count as misses and the lanes'
                # synchronous re-pull path remains the backstop.
                self.fetch_errors += 1
                self.fetch_misses += len(keys)
            finally:
                with self._lock:
                    for k in keys:
                        self._inflight.discard(k)
            self._check_deferred()

    def _check_deferred(self) -> None:
        """Resolve expected pushes: landed keys leave the inflight set,
        overdue keys re-enter the queue as ordinary pulls."""
        if not self._deferred:
            return
        now = time.monotonic()
        due: list[RegionKey] = []
        with self._lock:
            for k, deadline in list(self._deferred.items()):
                if self.store.where(k) is not None:
                    del self._deferred[k]
                    self._inflight.discard(k)
                    self.pushes_landed += 1
                elif now >= deadline:
                    del self._deferred[k]
                    due.append(k)  # stays inflight: queued for a pull
        for k in due:
            self._requests.put(k)

    def _local_hit(self, key: RegionKey) -> bool:
        """Serve ``key`` from a local tier if present (promote slow hits)."""
        where = self.store.where(key)
        if where is None:
            return False
        if where == self.store.tiers[0].name:
            self.already_resident += 1
        else:
            # Promote from a slow tier ahead of use.
            self.store.get(key, promote=True)
            self.prefetched += 1
        # on_staged fires on *every* success path: a region found in
        # a lower tier (e.g. the shared global store) is just as
        # newly-available to the consumer as a fetched one.
        if self.on_staged is not None:
            self.on_staged(key, 0)
        return True

    def _land(self, key: RegionKey, value: Any) -> int:
        nbytes = sizeof(value)
        self.store.put(key, value, tier=self.store.tiers[0].name, nbytes=nbytes)
        self.prefetched += 1
        self.prefetched_bytes += nbytes
        if self.on_staged is not None:
            self.on_staged(key, nbytes)
        return nbytes

    def _stage_batch(self, keys: list[RegionKey]) -> None:
        missing = [k for k in keys if not self._local_hit(k)]
        if not missing:
            return
        if self.dial is not None:
            # Coordinator bypass: pull straight from sibling holders;
            # whatever stays unresolved falls through to the relay.
            missing = self._direct_stage(missing)
            if not missing:
                return
        values = None
        if self.fetch_batch is not None:
            values = self.fetch_batch(missing)
            if values is not None:
                self.fetch_calls += 1
                self.batched_keys += len(missing)
        if values is not None:
            for k, v in zip(missing, values):
                if v is None:
                    self.fetch_misses += 1
                else:
                    self.relay_keys += 1
                    self.relay_bytes += self._land(k, v)
            return
        for k in missing:  # no batch source wired: per-key round-trips
            self._fetch_one(k)

    def _direct_stage(self, missing: list[RegionKey]) -> list[RegionKey]:
        """Worker-to-worker pull of ``missing``; returns the keys the
        direct path could not serve (unknown/stale/dead holder)."""
        holders: dict[RegionKey, tuple] = {}
        with self._lock:
            for k in missing:
                h = self._holders.get(k)
                if h is not None:
                    holders[k] = h
        unknown = [k for k in missing if k not in holders]
        if unknown and self.resolve is not None:
            try:
                resolved = self.resolve(unknown)
            except Exception:  # noqa: BLE001 - coordinator unreachable
                resolved = None
                self.fetch_errors += 1
            if resolved is not None:
                with self._lock:
                    for k, h in zip(unknown, resolved):
                        if h is not None:
                            h = (h[0], h[1])
                            holders[k] = h
                            self._holders[k] = h
        leftover = [k for k in missing if k not in holders]
        groups: dict[tuple, list[RegionKey]] = {}
        for k in missing:
            if k in holders:
                groups.setdefault(holders[k], []).append(k)
        for holder, hkeys in groups.items():
            try:
                values = self.dial(holder, hkeys)
            except Exception:  # noqa: BLE001 - peer dropped mid-pull
                values = None
                self.fetch_errors += 1
            if values is None:  # dead holder: forget it, use the relay
                self._forget_holder(holder[0], hkeys)
                leftover.extend(hkeys)
                continue
            self.fetch_calls += 1
            if len(hkeys) > 1:
                self.batched_keys += len(hkeys)
            for k, v in zip(hkeys, values):
                if v is None:
                    # Stale holder (spilled between notify and dial).
                    self.direct_misses += 1
                    self._forget_holder(holder[0], [k])
                    leftover.append(k)
                else:
                    self.direct_keys += 1
                    self.direct_bytes += self._land(k, v)
        return leftover

    def _forget_holder(self, worker_id: int, keys) -> None:
        with self._lock:
            for k in keys:
                h = self._holders.get(k)
                if h is not None and h[0] == worker_id:
                    del self._holders[k]

    def _stage(self, key: RegionKey) -> bool:
        if self._local_hit(key):
            return True
        if self.dial is not None and not self._direct_stage([key]):
            return True
        return self._fetch_one(key)

    def _fetch_one(self, key: RegionKey) -> bool:
        if self.fetch is None:
            self.fetch_misses += 1
            return False
        self.fetch_calls += 1
        value = self.fetch(key)
        if value is None:
            self.fetch_misses += 1
            return False
        self.relay_keys += 1
        self.relay_bytes += self._land(key, value)
        return True

    def stats(self) -> dict[str, int]:
        # Thin view over the registry cells, coerced to plain ints:
        # this dict rides the `get_stats` RPC.
        return {
            "prefetched": int(self.prefetched),
            "prefetched_bytes": int(self.prefetched_bytes),
            "already_resident": int(self.already_resident),
            "fetch_misses": int(self.fetch_misses),
            "demote_moves": int(self.demote_moves),
            "fetch_calls": int(self.fetch_calls),
            "batched_keys": int(self.batched_keys),
            "fetch_errors": int(self.fetch_errors),
            "direct_keys": int(self.direct_keys),
            "direct_bytes": int(self.direct_bytes),
            "direct_misses": int(self.direct_misses),
            "relay_keys": int(self.relay_keys),
            "relay_bytes": int(self.relay_bytes),
            "holder_invalidations": int(self.holder_invalidations),
            "pushes_expected": int(self.pushes_expected),
            "pushes_landed": int(self.pushes_landed),
        }
