"""Failover-surviving placement directory: write-ahead journal + snapshot.

The :class:`~repro.staging.directory.PlacementDirectory` is the
Manager's only copy of "which worker holds which region" — lose the
Manager and the whole cluster's locality metadata (and the record of
which leases were outstanding) dies with it.  :class:`DirectoryService`
wraps the directory so every mutation — placement records, evictions,
worker drops, lease grants, stage completions — is appended to a
:class:`WriteAheadJournal` *before* it is applied; a restarted Manager
replays the journal (newest snapshot + tail) and comes back with
holder maps and the pending-lease queue intact, then refetches any
region payloads it needs from the workers the directory says hold
them (the Manager journals metadata only, never payload bytes).

Journal format: one JSON object per line, ``{"e": <event>, ...}``.
A snapshot (written every ``snapshot_every`` appends) serializes the
full directory + lease state into ``<path>.snap`` and truncates the
journal, bounding replay time — the classic WAL/checkpoint pair.

**Size-tiered incremental checkpoints** (``incremental=True``): a hot
serving directory never stops mutating, so full snapshots grow with
total state and the checkpoint pause grows with them.  In incremental
mode a checkpoint instead writes only the state *touched since the
last checkpoint* to a delta file ``<path>.snap.dNNNNNN`` (placement
keys with their full current holder maps — an empty map is a
tombstone — newly completed uids, dirty leases, the pending list,
dirty addresses/racks, dropped workers) and truncates the journal.
Deltas are folded into a fresh full snapshot (compaction) once their
accumulated bytes reach the base snapshot's size or their count
reaches ``compact_deltas`` — the classic size-tiered trade: checkpoint
pause proportional to churn, replay cost bounded by base + O(churn).
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Iterable, Optional

from .directory import PlacementDirectory
from .tiers import RegionKey

__all__ = ["WriteAheadJournal", "DirectoryService", "decode_key"]


def _jsonable_key(key: RegionKey) -> Any:
    if isinstance(key, tuple):
        return list(key)
    return key


def decode_key(key: Any) -> RegionKey:
    """Region keys are tuples in memory but lists on JSON/wire formats;
    normalize so directory lookups match (shared with repro.transport)."""
    if isinstance(key, list):
        return tuple(key)
    return key


class WriteAheadJournal:
    """Append-only JSON-lines journal with a sidecar snapshot file."""

    def __init__(self, path: str, *, fsync: bool = False):
        self.path = path
        self.snap_path = path + ".snap"
        directory = os.path.dirname(os.path.abspath(path))
        os.makedirs(directory, exist_ok=True)
        self.appends = 0
        self.fsync = fsync  # flush always; fsync only when durability > rate
        self._lock = threading.Lock()
        self._repair_torn_tail(path)
        self._fh = open(path, "a", encoding="utf-8")  # noqa: SIM115
        # Journal bytes accumulated since the last truncating snapshot —
        # the byte-based compaction trigger reads this, so an existing
        # (replayed) tail counts toward the first checkpoint too.
        try:
            self.appended_bytes = os.path.getsize(path)
        except OSError:
            self.appended_bytes = 0
        # Incremental-checkpoint sequencing: continue numbering after
        # any delta files a previous incarnation left behind, so replay
        # order (lexicographic = numeric) stays correct across restarts.
        seqs = [s for s, _ in self._delta_files()]
        self.delta_seq = max(seqs) if seqs else 0

    def _delta_files(self) -> list[tuple[int, str]]:
        """Existing delta files as sorted ``(seq, path)`` pairs."""
        directory = os.path.dirname(os.path.abspath(self.snap_path))
        prefix = os.path.basename(self.snap_path) + ".d"
        out: list[tuple[int, str]] = []
        try:
            names = os.listdir(directory)
        except OSError:
            return out
        for name in names:
            if not name.startswith(prefix) or name.endswith(".tmp"):
                continue
            try:
                seq = int(name[len(prefix):])
            except ValueError:
                continue
            out.append((seq, os.path.join(directory, name)))
        out.sort()
        return out

    @staticmethod
    def _repair_torn_tail(path: str) -> None:
        """Truncate a half-written final line left by a crash.

        Appending onto a torn fragment would corrupt that line AND make
        ``load`` (which stops at the first bad line) silently discard
        every valid entry written after the restart — so the fragment
        is cut back to the last newline before the file is reopened.
        """
        try:
            size = os.path.getsize(path)
        except OSError:
            return
        if size == 0:
            return
        with open(path, "rb") as f:
            f.seek(-min(size, 1 << 20), os.SEEK_END)
            tail = f.read()
        if tail.endswith(b"\n"):
            return
        keep = size - (len(tail) - (tail.rfind(b"\n") + 1))
        with open(path, "rb+") as f:
            f.truncate(keep)

    def append(self, entry: dict[str, Any]) -> None:
        with self._lock:
            line = json.dumps(entry, separators=(",", ":")) + "\n"
            self._fh.write(line)
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
            self.appends += 1
            self.appended_bytes += len(line)

    def snapshot(self, state: dict[str, Any]) -> int:
        """Full checkpoint: persist ``state``, truncate the journal and
        delete any delta files (their contents are folded in).  Returns
        the snapshot's size in bytes (the base for size-tiered
        compaction triggers)."""
        with self._lock:
            tmp = self.snap_path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(state, f, separators=(",", ":"))
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.snap_path)
            for _, dpath in self._delta_files():
                try:
                    os.remove(dpath)
                except OSError:
                    pass
            self._truncate_locked()
            try:
                return os.path.getsize(self.snap_path)
            except OSError:
                return 0

    def delta(self, state: dict[str, Any]) -> int:
        """Incremental checkpoint: persist the dirty-state ``state`` to
        the next ``<snap>.dNNNNNN`` file, then truncate the journal.
        The delta is durable *before* the journal entries it subsumes
        are dropped (same ordering contract as ``snapshot``).  Returns
        the delta's size in bytes."""
        with self._lock:
            self.delta_seq += 1
            dpath = f"{self.snap_path}.d{self.delta_seq:06d}"
            tmp = dpath + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(state, f, separators=(",", ":"))
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, dpath)
            self._truncate_locked()
            try:
                return os.path.getsize(dpath)
            except OSError:
                return 0

    def _truncate_locked(self) -> None:
        self._fh.close()
        self._fh = open(self.path, "w", encoding="utf-8")  # noqa: SIM115
        self.appended_bytes = 0

    def close(self) -> None:
        with self._lock:
            self._fh.close()

    @classmethod
    def load(cls, path: str) -> tuple[Optional[dict], list[dict], list[dict]]:
        """Newest full snapshot (or None), the incremental deltas after
        it (oldest first), and the journal tail after those."""
        snapshot = None
        snap_path = path + ".snap"
        if os.path.exists(snap_path):
            with open(snap_path, encoding="utf-8") as f:
                snapshot = json.load(f)
        deltas: list[dict] = []
        directory = os.path.dirname(os.path.abspath(snap_path))
        prefix = os.path.basename(snap_path) + ".d"
        try:
            names = sorted(
                n for n in os.listdir(directory)
                if n.startswith(prefix) and not n.endswith(".tmp")
            )
        except OSError:
            names = []
        for name in names:
            try:
                with open(os.path.join(directory, name), encoding="utf-8") as f:
                    deltas.append(json.load(f))
            except (OSError, json.JSONDecodeError):
                break  # torn delta: stop here, journal tail is gone anyway
        entries: list[dict] = []
        if os.path.exists(path):
            with open(path, encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        entries.append(json.loads(line))
                    except json.JSONDecodeError:
                        break  # torn tail write: everything before it is good
        return snapshot, deltas, entries


class DirectoryService:
    """A PlacementDirectory whose state survives the Manager.

    Same query surface as the directory (delegated); mutations are
    journaled write-ahead.  Additionally journals the Manager's lease
    lifecycle (``pending`` / ``lease`` / ``complete``) so a rehydrated
    Manager knows which stage instances were done and which were in
    flight when the coordinator died.

    Opening a path that already has a journal/snapshot *replays* it:
    ``DirectoryService(path)`` after a crash is the failover story.
    """

    def __init__(
        self,
        path: str,
        directory: Optional[PlacementDirectory] = None,
        *,
        snapshot_every: int = 512,
        snapshot_bytes: Optional[int] = None,
        incremental: bool = False,
        compact_deltas: int = 8,
        registry=None,
    ):
        from ..telemetry.metrics import MetricsRegistry

        self.directory = directory or PlacementDirectory()
        self.registry = registry or MetricsRegistry()
        self.snapshot_every = max(int(snapshot_every), 1)
        # Byte-keyed compaction: when set, a checkpoint triggers once the
        # journal grows past this many bytes since the last snapshot —
        # replay time is bounded by bytes-to-parse, not append count
        # (entries vary 20x in size), so this is the scale-stable knob.
        self.snapshot_bytes = snapshot_bytes
        # Size-tiered incremental checkpoints: deltas of dirty state
        # instead of full snapshots, compacted once delta bytes reach
        # the base snapshot size or ``compact_deltas`` files pile up.
        self.incremental = bool(incremental)
        self.compact_deltas = max(int(compact_deltas), 1)
        # Serializes append+apply against checkpoint: an entry journaled
        # by one thread while another builds the snapshot state must not
        # be truncated away with its mutation in neither file (mutators
        # arrive from Manager, endpoint dispatcher, and worker threads).
        self._mu = threading.RLock()
        self.completed: set[int] = set()
        self.leases: dict[int, int] = {}     # stage uid -> worker id
        self.pending: list[int] = []         # noted, never completed
        # Registry-served counters (int-like cells; see repro.telemetry).
        self.replayed = self.registry.counter("directory.replayed")
        self.full_checkpoints = self.registry.counter(
            "directory.full_checkpoints"
        )
        self.delta_checkpoints = self.registry.counter(
            "directory.delta_checkpoints"
        )
        # Dirty state since the last checkpoint (incremental mode).
        self._dirty_keys: set[RegionKey] = set()
        self._dirty_leases: set[int] = set()
        self._completed_new: set[int] = set()
        self._dirty_addrs: set[int] = set()
        self._dirty_racks: set[int] = set()
        self._dropped: set[int] = set()
        snapshot, deltas, entries = WriteAheadJournal.load(path)
        if snapshot is not None:
            self._apply_snapshot(snapshot)
        for delta in deltas:
            self._apply_delta(delta)
        for entry in entries:
            self._apply(entry)
            self.replayed += 1
        self.journal = WriteAheadJournal(path)
        self._mutations = 0
        # Compaction accounting: the base snapshot's size and the delta
        # bytes stacked on top of it since.
        self._base_bytes = 0
        self._delta_bytes = 0
        self._delta_count = len(deltas)
        if snapshot is not None:
            try:
                self._base_bytes = os.path.getsize(self.journal.snap_path)
            except OSError:
                self._base_bytes = 0
        for _, dpath in self.journal._delta_files():  # noqa: SLF001
            try:
                self._delta_bytes += os.path.getsize(dpath)
            except OSError:
                pass

    # -- replay ------------------------------------------------------------

    def _apply_snapshot(self, snap: dict) -> None:
        for key_json, holders in snap.get("placement", []):
            key = decode_key(key_json)
            for wid, nbytes in holders.items():
                self.directory.record(int(wid), key, int(nbytes))
        self.completed = set(snap.get("completed", []))
        self.leases = {int(k): int(v) for k, v in snap.get("leases", {}).items()}
        self.pending = list(snap.get("pending", []))
        for wid, addr in snap.get("addresses", {}).items():
            self.directory.set_address(int(wid), addr)
        for wid, rack in snap.get("racks", {}).items():
            self.directory.set_rack(int(wid), rack)

    def _apply(self, entry: dict) -> None:
        e = entry.get("e")
        if e == "rec":
            key = decode_key(entry["k"])
            self._mark_key(key)
            self.directory.record(int(entry["w"]), key, int(entry["n"]))
        elif e == "evi":
            key = decode_key(entry["k"])
            self._mark_key(key)
            self.directory.evict(int(entry["w"]), key)
        elif e == "addr":
            self._mark_addr(int(entry["w"]))
            self.directory.set_address(int(entry["w"]), entry["a"])
        elif e == "rack":
            self._mark_rack(int(entry["w"]))
            self.directory.set_rack(int(entry["w"]), entry["r"])
        elif e == "drop":
            wid = int(entry["w"])
            self._mark_drop(wid)
            self.directory.drop_worker(wid)
            for uid, lw in self.leases.items():
                if lw == wid:
                    self._mark_lease(uid)
            self.leases = {
                uid: w for uid, w in self.leases.items() if w != wid
            }
        elif e == "pend":
            uid = int(entry["u"])
            if uid not in self.pending:
                self.pending.append(uid)
        elif e == "lease":
            uid = int(entry["u"])
            self._mark_lease(uid)
            self.leases[uid] = int(entry["w"])
        elif e == "done":
            uid = int(entry["u"])
            self._mark_done(uid)
            self.completed.add(uid)
            self.leases.pop(uid, None)
            if uid in self.pending:
                self.pending.remove(uid)

    def _apply_delta(self, delta: dict) -> None:
        """Replay one incremental checkpoint.  Ordering matters: worker
        drops first (they clear placements wholesale), then the dirty
        placement keys — each carries its FULL holder map as of the
        checkpoint, so replace-don't-merge; an empty map is a tombstone
        — then lease/complete/pending state and identities."""
        for wid in delta.get("dropped", []):
            self.directory.drop_worker(int(wid))
        for key_json, holders in delta.get("placement", []):
            key = decode_key(key_json)
            for w in list(self.directory.holders(key)):
                self.directory.evict(w, key)
            for w, n in holders.items():
                self.directory.record(int(w), key, int(n))
        self.completed.update(int(u) for u in delta.get("completed_add", []))
        for u, w in delta.get("leases", {}).items():
            if w is None:
                self.leases.pop(int(u), None)
            else:
                self.leases[int(u)] = int(w)
        if "pending" in delta:
            self.pending = [int(u) for u in delta["pending"]]
        for wid, addr in delta.get("addresses", {}).items():
            self.directory.set_address(int(wid), addr)
        for wid, rack in delta.get("racks", {}).items():
            self.directory.set_rack(int(wid), rack)

    # -- dirty tracking (incremental checkpoints) --------------------------

    def _mark_key(self, key: RegionKey) -> None:
        if self.incremental:
            self._dirty_keys.add(key)

    def _mark_lease(self, uid: int) -> None:
        if self.incremental:
            self._dirty_leases.add(uid)

    def _mark_done(self, uid: int) -> None:
        if self.incremental:
            self._completed_new.add(uid)
            self._dirty_leases.add(uid)

    def _mark_addr(self, wid: int) -> None:
        if self.incremental:
            self._dirty_addrs.add(wid)

    def _mark_rack(self, wid: int) -> None:
        if self.incremental:
            self._dirty_racks.add(wid)

    def _mark_drop(self, wid: int) -> None:
        """A worker drop dirties every key it held (their holder maps
        change) plus its address/rack.  Enumerated BEFORE the drop is
        applied; drops are rare (elastic membership events), so the
        scan is off the hot path."""
        if not self.incremental:
            return
        self._dropped.add(wid)
        self._dirty_addrs.add(wid)
        self._dirty_racks.add(wid)
        d = self.directory
        with d._lock:  # noqa: SLF001 - consistent view of the map
            for key, holders in d._placement.items():  # noqa: SLF001
                if wid in holders:
                    self._dirty_keys.add(key)

    # -- journaled mutations ----------------------------------------------

    def _log(self, entry: dict) -> None:
        """Write-ahead append.  The periodic checkpoint runs from
        ``_applied`` — after the in-memory state reflects the entry —
        so a snapshot can never miss the mutation that triggered it."""
        self.journal.append(entry)

    def _applied(self) -> None:
        self._mutations += 1
        if self.snapshot_bytes is not None:
            if self.journal.appended_bytes >= self.snapshot_bytes:
                self.checkpoint()
        elif self._mutations % self.snapshot_every == 0:
            self.checkpoint()

    def record(self, worker_id: int, key: RegionKey, nbytes: int) -> None:
        with self._mu:
            self._log(
                {"e": "rec", "w": worker_id, "k": _jsonable_key(key), "n": nbytes}
            )
            self._mark_key(key)
            self.directory.record(worker_id, key, nbytes)
            self._applied()

    def set_address(self, worker_id: int, address: Any) -> None:
        """Journal a worker's data-plane bus address: a rehydrated
        coordinator can answer holder lookups with dialable peers even
        before the workers re-register (stale addresses fail the dial
        and fall back to the Manager route, so this is best-effort)."""
        with self._mu:
            self._log({"e": "addr", "w": worker_id, "a": address})
            self._mark_addr(worker_id)
            self.directory.set_address(worker_id, address)
            self._applied()

    def set_rack(self, worker_id: int, rack: Any) -> None:
        """Journal a worker's rack (network topology identity): a
        rehydrated coordinator keeps scoring rack-locality correctly
        before the workers re-register."""
        with self._mu:
            self._log({"e": "rack", "w": worker_id, "r": rack})
            self._mark_rack(worker_id)
            self.directory.set_rack(worker_id, rack)
            self._applied()

    def evict(self, worker_id: int, key: RegionKey) -> None:
        with self._mu:
            self._log({"e": "evi", "w": worker_id, "k": _jsonable_key(key)})
            self._mark_key(key)
            self.directory.evict(worker_id, key)
            self._applied()

    def drop_worker(self, worker_id: int) -> None:
        with self._mu:
            self._log({"e": "drop", "w": worker_id})
            self._mark_drop(worker_id)
            self.directory.drop_worker(worker_id)
            for uid, wid in self.leases.items():
                if wid == worker_id:
                    self._mark_lease(uid)
            self.leases = {
                uid: wid for uid, wid in self.leases.items() if wid != worker_id
            }
            self._applied()

    # -- lease lifecycle (Manager hooks) -----------------------------------

    def note_pending(self, uid: int) -> None:
        with self._mu:
            if uid not in self.pending:
                self._log({"e": "pend", "u": uid})
                self.pending.append(uid)
                self._applied()

    def note_lease(self, uid: int, worker_id: int) -> None:
        with self._mu:
            self._log({"e": "lease", "u": uid, "w": worker_id})
            self._mark_lease(uid)
            self.leases[uid] = worker_id
            self._applied()

    def note_complete(self, uid: int) -> None:
        with self._mu:
            self._log({"e": "done", "u": uid})
            self._mark_done(uid)
            self.completed.add(uid)
            self.leases.pop(uid, None)
            if uid in self.pending:
                self.pending.remove(uid)
            self._applied()

    def stats(self) -> dict[str, int]:
        """Thin int view over the registry cells (wire-safe)."""
        return {
            "replayed": int(self.replayed),
            "full_checkpoints": int(self.full_checkpoints),
            "delta_checkpoints": int(self.delta_checkpoints),
            "journal_appends": int(self.journal.appends),
        }

    def outstanding(self) -> list[int]:
        """Stage uids that were pending or leased but never completed —
        the work a rehydrated Manager must put back on the queue."""
        out = [u for u in self.pending if u not in self.completed]
        out += [
            u for u in self.leases
            if u not in self.completed and u not in out
        ]
        return out

    # -- checkpoint --------------------------------------------------------

    def checkpoint(self) -> None:
        with self._mu:
            self._checkpoint_locked()

    def _checkpoint_locked(self) -> None:
        if self.incremental:
            self._incremental_checkpoint_locked()
        else:
            self._full_checkpoint_locked()

    def _incremental_checkpoint_locked(self) -> None:
        """Size-tiered checkpoint: write only the dirty state as a
        delta; fold everything into a fresh full snapshot once the
        stacked deltas reach the base snapshot's size (or pile up past
        ``compact_deltas``).  First checkpoint ever is always full —
        there is no base to be incremental against."""
        compact = (
            self._base_bytes <= 0
            or self._delta_count + 1 > self.compact_deltas
            or (self._delta_bytes >= self._base_bytes > 0)
        )
        if compact:
            self._full_checkpoint_locked()
            return
        d = self.directory
        with d._lock:  # noqa: SLF001 - consistent view of dirty keys
            placement = [
                [
                    _jsonable_key(k),
                    {
                        str(w): n
                        for w, n in d._placement.get(k, {}).items()  # noqa: SLF001
                    },
                ]
                for k in self._dirty_keys
            ]
        delta = {
            "dropped": sorted(self._dropped),
            "placement": placement,
            "completed_add": sorted(self._completed_new),
            "leases": {
                str(u): self.leases.get(u) for u in self._dirty_leases
            },
            "pending": list(self.pending),
            "addresses": {
                str(w): self.directory.address_of(w)
                for w in self._dirty_addrs
                if self.directory.address_of(w) is not None
            },
            "racks": {
                str(w): self.directory.rack_of(w)
                for w in self._dirty_racks
                if self.directory.rack_of(w) is not None
            },
        }
        self._delta_bytes += self.journal.delta(delta)
        self._delta_count += 1
        self.delta_checkpoints += 1
        self._clear_dirty_locked()

    def _clear_dirty_locked(self) -> None:
        self._dirty_keys.clear()
        self._dirty_leases.clear()
        self._completed_new.clear()
        self._dirty_addrs.clear()
        self._dirty_racks.clear()
        self._dropped.clear()

    def _full_checkpoint_locked(self) -> None:
        state = {
            "placement": [
                [_jsonable_key(k), {str(w): n for w, n in holders.items()}]
                for k, holders in self._placement_items()
            ],
            "completed": sorted(self.completed),
            "leases": {str(u): w for u, w in self.leases.items()},
            "pending": list(self.pending),
            "addresses": {
                str(w): a for w, a in self.directory.addresses().items()
            },
            "racks": {
                str(w): r for w, r in self.directory.racks().items()
            },
        }
        self._base_bytes = self.journal.snapshot(state)
        self._delta_bytes = 0
        self._delta_count = 0
        self.full_checkpoints += 1
        self._clear_dirty_locked()

    def _placement_items(self) -> Iterable[tuple[RegionKey, dict[int, int]]]:
        d = self.directory
        with d._lock:  # noqa: SLF001 - consistent snapshot of the map
            return [(k, dict(h)) for k, h in d._placement.items()]  # noqa: SLF001

    def close(self) -> None:
        self.journal.close()

    # -- query delegation --------------------------------------------------

    def __getattr__(self, name: str) -> Any:
        return getattr(self.directory, name)

    def __len__(self) -> int:
        return len(self.directory)
