"""Failover-surviving placement directory: write-ahead journal + snapshot.

The :class:`~repro.staging.directory.PlacementDirectory` is the
Manager's only copy of "which worker holds which region" — lose the
Manager and the whole cluster's locality metadata (and the record of
which leases were outstanding) dies with it.  :class:`DirectoryService`
wraps the directory so every mutation — placement records, evictions,
worker drops, lease grants, stage completions — is appended to a
:class:`WriteAheadJournal` *before* it is applied; a restarted Manager
replays the journal (newest snapshot + tail) and comes back with
holder maps and the pending-lease queue intact, then refetches any
region payloads it needs from the workers the directory says hold
them (the Manager journals metadata only, never payload bytes).

Journal format: one JSON object per line, ``{"e": <event>, ...}``.
A snapshot (written every ``snapshot_every`` appends) serializes the
full directory + lease state into ``<path>.snap`` and truncates the
journal, bounding replay time — the classic WAL/checkpoint pair.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Iterable, Optional

from .directory import PlacementDirectory
from .tiers import RegionKey

__all__ = ["WriteAheadJournal", "DirectoryService", "decode_key"]


def _jsonable_key(key: RegionKey) -> Any:
    if isinstance(key, tuple):
        return list(key)
    return key


def decode_key(key: Any) -> RegionKey:
    """Region keys are tuples in memory but lists on JSON/wire formats;
    normalize so directory lookups match (shared with repro.transport)."""
    if isinstance(key, list):
        return tuple(key)
    return key


class WriteAheadJournal:
    """Append-only JSON-lines journal with a sidecar snapshot file."""

    def __init__(self, path: str, *, fsync: bool = False):
        self.path = path
        self.snap_path = path + ".snap"
        directory = os.path.dirname(os.path.abspath(path))
        os.makedirs(directory, exist_ok=True)
        self.appends = 0
        self.fsync = fsync  # flush always; fsync only when durability > rate
        self._lock = threading.Lock()
        self._repair_torn_tail(path)
        self._fh = open(path, "a", encoding="utf-8")  # noqa: SIM115
        # Journal bytes accumulated since the last truncating snapshot —
        # the byte-based compaction trigger reads this, so an existing
        # (replayed) tail counts toward the first checkpoint too.
        try:
            self.appended_bytes = os.path.getsize(path)
        except OSError:
            self.appended_bytes = 0

    @staticmethod
    def _repair_torn_tail(path: str) -> None:
        """Truncate a half-written final line left by a crash.

        Appending onto a torn fragment would corrupt that line AND make
        ``load`` (which stops at the first bad line) silently discard
        every valid entry written after the restart — so the fragment
        is cut back to the last newline before the file is reopened.
        """
        try:
            size = os.path.getsize(path)
        except OSError:
            return
        if size == 0:
            return
        with open(path, "rb") as f:
            f.seek(-min(size, 1 << 20), os.SEEK_END)
            tail = f.read()
        if tail.endswith(b"\n"):
            return
        keep = size - (len(tail) - (tail.rfind(b"\n") + 1))
        with open(path, "rb+") as f:
            f.truncate(keep)

    def append(self, entry: dict[str, Any]) -> None:
        with self._lock:
            line = json.dumps(entry, separators=(",", ":")) + "\n"
            self._fh.write(line)
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
            self.appends += 1
            self.appended_bytes += len(line)

    def snapshot(self, state: dict[str, Any]) -> None:
        """Checkpoint: persist ``state``, then truncate the journal."""
        with self._lock:
            tmp = self.snap_path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(state, f, separators=(",", ":"))
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.snap_path)
            self._fh.close()
            self._fh = open(self.path, "w", encoding="utf-8")  # noqa: SIM115
            self.appended_bytes = 0

    def close(self) -> None:
        with self._lock:
            self._fh.close()

    @classmethod
    def load(cls, path: str) -> tuple[Optional[dict], list[dict]]:
        """Newest snapshot (or None) plus the journal tail after it."""
        snapshot = None
        snap_path = path + ".snap"
        if os.path.exists(snap_path):
            with open(snap_path, encoding="utf-8") as f:
                snapshot = json.load(f)
        entries: list[dict] = []
        if os.path.exists(path):
            with open(path, encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        entries.append(json.loads(line))
                    except json.JSONDecodeError:
                        break  # torn tail write: everything before it is good
        return snapshot, entries


class DirectoryService:
    """A PlacementDirectory whose state survives the Manager.

    Same query surface as the directory (delegated); mutations are
    journaled write-ahead.  Additionally journals the Manager's lease
    lifecycle (``pending`` / ``lease`` / ``complete``) so a rehydrated
    Manager knows which stage instances were done and which were in
    flight when the coordinator died.

    Opening a path that already has a journal/snapshot *replays* it:
    ``DirectoryService(path)`` after a crash is the failover story.
    """

    def __init__(
        self,
        path: str,
        directory: Optional[PlacementDirectory] = None,
        *,
        snapshot_every: int = 512,
        snapshot_bytes: Optional[int] = None,
    ):
        self.directory = directory or PlacementDirectory()
        self.snapshot_every = max(int(snapshot_every), 1)
        # Byte-keyed compaction: when set, a checkpoint triggers once the
        # journal grows past this many bytes since the last snapshot —
        # replay time is bounded by bytes-to-parse, not append count
        # (entries vary 20x in size), so this is the scale-stable knob.
        self.snapshot_bytes = snapshot_bytes
        # Serializes append+apply against checkpoint: an entry journaled
        # by one thread while another builds the snapshot state must not
        # be truncated away with its mutation in neither file (mutators
        # arrive from Manager, endpoint dispatcher, and worker threads).
        self._mu = threading.RLock()
        self.completed: set[int] = set()
        self.leases: dict[int, int] = {}     # stage uid -> worker id
        self.pending: list[int] = []         # noted, never completed
        self.replayed = 0
        snapshot, entries = WriteAheadJournal.load(path)
        if snapshot is not None:
            self._apply_snapshot(snapshot)
        for entry in entries:
            self._apply(entry)
            self.replayed += 1
        self.journal = WriteAheadJournal(path)
        self._mutations = 0

    # -- replay ------------------------------------------------------------

    def _apply_snapshot(self, snap: dict) -> None:
        for key_json, holders in snap.get("placement", []):
            key = decode_key(key_json)
            for wid, nbytes in holders.items():
                self.directory.record(int(wid), key, int(nbytes))
        self.completed = set(snap.get("completed", []))
        self.leases = {int(k): int(v) for k, v in snap.get("leases", {}).items()}
        self.pending = list(snap.get("pending", []))
        for wid, addr in snap.get("addresses", {}).items():
            self.directory.set_address(int(wid), addr)
        for wid, rack in snap.get("racks", {}).items():
            self.directory.set_rack(int(wid), rack)

    def _apply(self, entry: dict) -> None:
        e = entry.get("e")
        if e == "rec":
            self.directory.record(
                int(entry["w"]), decode_key(entry["k"]), int(entry["n"])
            )
        elif e == "evi":
            self.directory.evict(int(entry["w"]), decode_key(entry["k"]))
        elif e == "addr":
            self.directory.set_address(int(entry["w"]), entry["a"])
        elif e == "rack":
            self.directory.set_rack(int(entry["w"]), entry["r"])
        elif e == "drop":
            self.directory.drop_worker(int(entry["w"]))
            self.leases = {
                uid: wid for uid, wid in self.leases.items()
                if wid != int(entry["w"])
            }
        elif e == "pend":
            uid = int(entry["u"])
            if uid not in self.pending:
                self.pending.append(uid)
        elif e == "lease":
            self.leases[int(entry["u"])] = int(entry["w"])
        elif e == "done":
            uid = int(entry["u"])
            self.completed.add(uid)
            self.leases.pop(uid, None)
            if uid in self.pending:
                self.pending.remove(uid)

    # -- journaled mutations ----------------------------------------------

    def _log(self, entry: dict) -> None:
        """Write-ahead append.  The periodic checkpoint runs from
        ``_applied`` — after the in-memory state reflects the entry —
        so a snapshot can never miss the mutation that triggered it."""
        self.journal.append(entry)

    def _applied(self) -> None:
        self._mutations += 1
        if self.snapshot_bytes is not None:
            if self.journal.appended_bytes >= self.snapshot_bytes:
                self.checkpoint()
        elif self._mutations % self.snapshot_every == 0:
            self.checkpoint()

    def record(self, worker_id: int, key: RegionKey, nbytes: int) -> None:
        with self._mu:
            self._log(
                {"e": "rec", "w": worker_id, "k": _jsonable_key(key), "n": nbytes}
            )
            self.directory.record(worker_id, key, nbytes)
            self._applied()

    def set_address(self, worker_id: int, address: Any) -> None:
        """Journal a worker's data-plane bus address: a rehydrated
        coordinator can answer holder lookups with dialable peers even
        before the workers re-register (stale addresses fail the dial
        and fall back to the Manager route, so this is best-effort)."""
        with self._mu:
            self._log({"e": "addr", "w": worker_id, "a": address})
            self.directory.set_address(worker_id, address)
            self._applied()

    def set_rack(self, worker_id: int, rack: Any) -> None:
        """Journal a worker's rack (network topology identity): a
        rehydrated coordinator keeps scoring rack-locality correctly
        before the workers re-register."""
        with self._mu:
            self._log({"e": "rack", "w": worker_id, "r": rack})
            self.directory.set_rack(worker_id, rack)
            self._applied()

    def evict(self, worker_id: int, key: RegionKey) -> None:
        with self._mu:
            self._log({"e": "evi", "w": worker_id, "k": _jsonable_key(key)})
            self.directory.evict(worker_id, key)
            self._applied()

    def drop_worker(self, worker_id: int) -> None:
        with self._mu:
            self._log({"e": "drop", "w": worker_id})
            self.directory.drop_worker(worker_id)
            self.leases = {
                uid: wid for uid, wid in self.leases.items() if wid != worker_id
            }
            self._applied()

    # -- lease lifecycle (Manager hooks) -----------------------------------

    def note_pending(self, uid: int) -> None:
        with self._mu:
            if uid not in self.pending:
                self._log({"e": "pend", "u": uid})
                self.pending.append(uid)
                self._applied()

    def note_lease(self, uid: int, worker_id: int) -> None:
        with self._mu:
            self._log({"e": "lease", "u": uid, "w": worker_id})
            self.leases[uid] = worker_id
            self._applied()

    def note_complete(self, uid: int) -> None:
        with self._mu:
            self._log({"e": "done", "u": uid})
            self.completed.add(uid)
            self.leases.pop(uid, None)
            if uid in self.pending:
                self.pending.remove(uid)
            self._applied()

    def outstanding(self) -> list[int]:
        """Stage uids that were pending or leased but never completed —
        the work a rehydrated Manager must put back on the queue."""
        out = [u for u in self.pending if u not in self.completed]
        out += [
            u for u in self.leases
            if u not in self.completed and u not in out
        ]
        return out

    # -- checkpoint --------------------------------------------------------

    def checkpoint(self) -> None:
        with self._mu:
            self._checkpoint_locked()

    def _checkpoint_locked(self) -> None:
        state = {
            "placement": [
                [_jsonable_key(k), {str(w): n for w, n in holders.items()}]
                for k, holders in self._placement_items()
            ],
            "completed": sorted(self.completed),
            "leases": {str(u): w for u, w in self.leases.items()},
            "pending": list(self.pending),
            "addresses": {
                str(w): a for w, a in self.directory.addresses().items()
            },
            "racks": {
                str(w): r for w, r in self.directory.racks().items()
            },
        }
        self.journal.snapshot(state)

    def _placement_items(self) -> Iterable[tuple[RegionKey, dict[int, int]]]:
        d = self.directory
        with d._lock:  # noqa: SLF001 - consistent snapshot of the map
            return [(k, dict(h)) for k, h in d._placement.items()]  # noqa: SLF001

    def close(self) -> None:
        self.journal.close()

    # -- query delegation --------------------------------------------------

    def __getattr__(self, name: str) -> Any:
        return getattr(self.directory, name)

    def __len__(self) -> int:
        return len(self.directory)
