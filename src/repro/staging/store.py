"""Content-addressed hierarchical region store.

A *region* is any named blob the runtime moves around: an operation
instance's output, a staged input batch, a tile read from the global
store.  Regions are addressed by structured keys —

* ``("op", uid)``      — output of operation instance ``uid``;
* ``("chunk", cid)``   — materialized input chunk ``cid``;
* ``("blob", digest)`` — true content address (see :func:`content_key`).

The store stacks :mod:`~repro.staging.tiers` fastest-first and provides
the two primitives everything else builds on:

* ``put`` into a chosen tier, demoting evicted entries down the stack;
* ``get`` searching top-down, optionally *promoting* the hit so the
  next access is faster (the paper's reuse-conscious hierarchy).
"""

from __future__ import annotations

import hashlib
import pickle
import threading
from typing import Any, Callable, Optional, Sequence

from .tiers import RegionKey, Tier, sizeof

__all__ = ["RegionStore", "op_key", "chunk_key", "content_key"]


def op_key(uid: int) -> RegionKey:
    """Key for the output of operation instance ``uid``."""
    return ("op", uid)


def chunk_key(chunk_id: int) -> RegionKey:
    """Key for a materialized input data chunk."""
    return ("chunk", chunk_id)


def content_key(value: Any) -> RegionKey:
    """True content address: sha1 over the pickled payload."""
    digest = hashlib.sha1(
        pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL)
    ).hexdigest()
    return ("blob", digest)


class RegionStore:
    """Ordered stack of tiers with promote/demote movement."""

    def __init__(self, tiers: Sequence[Tier], *, demote: bool = True,
                 registry=None):
        from ..telemetry.metrics import MetricsRegistry

        if not tiers:
            raise ValueError("RegionStore needs at least one tier")
        names = [t.name for t in tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        self.tiers = list(tiers)
        self.demote = demote
        self._lock = threading.RLock()
        # Movement counters (cluster benchmarks read these) — int-like
        # cells in the shared metrics registry.
        self.registry = registry or MetricsRegistry()
        self.promotions = self.registry.counter("store.promotions")
        self.demotions = self.registry.counter("store.demotions")
        self.promoted_bytes = self.registry.counter("store.promoted_bytes")
        self.demoted_bytes = self.registry.counter("store.demoted_bytes")
        # Regions destroyed because the bottom tier evicted them with
        # no deeper backstop — nonzero means tier budgets are too tight
        # for the unpinned working set (diagnostic, see stats()).
        self.dropped = self.registry.counter("store.dropped")
        # Fired when a region leaves this store entirely (fell off the
        # bottom tier).  The Manager wires it to PlacementDirectory.
        # evict so the directory's replica map — which feeds lease
        # placement and replication-aware eviction — never goes stale.
        self.on_drop: Optional[Callable[[RegionKey], None]] = None

    # -- tier lookup -------------------------------------------------------

    def tier(self, name: str) -> Tier:
        for t in self.tiers:
            if t.name == name:
                return t
        raise KeyError(f"no tier named {name!r}")

    def _tier_index(self, name: Optional[str]) -> int:
        if name is None:
            return 0
        for i, t in enumerate(self.tiers):
            if t.name == name:
                return i
        raise KeyError(f"no tier named {name!r}")

    # -- storage -----------------------------------------------------------

    def put(
        self,
        key: RegionKey,
        value: Any,
        *,
        tier: Optional[str] = None,
        nbytes: Optional[int] = None,
    ) -> int:
        """Store ``key`` in ``tier`` (default: fastest); returns nbytes.

        Entries the target tier evicts cascade down the stack (RAM spills
        to disk, disk drops — the global tier is never evicted into from
        a drop whose payload is gone).
        """
        nbytes = sizeof(value) if nbytes is None else nbytes
        with self._lock:
            i = self._tier_index(tier)
            evicted = self.tiers[i].put(key, value, nbytes)
            self._demote_from(i, evicted)
        return nbytes

    def _demote_from(self, i: int, evicted: list) -> None:
        if not self.demote:
            return
        nxt = i + 1
        if nxt >= len(self.tiers):
            for k, v, _ in evicted:
                if v is None:
                    continue
                self.dropped += 1
                if self.on_drop is not None:
                    try:
                        self.on_drop(k)
                    except Exception:  # noqa: BLE001 - directory gone
                        pass
            return
        for k, v, n in evicted:
            if v is None:
                # Payload already gone (device memory / disk drop): the
                # region survives only where another tier holds it.
                continue
            self.demotions += 1
            self.demoted_bytes += n
            deeper = self.tiers[nxt].put(k, v, n)
            self._demote_from(nxt, deeper)

    def get(
        self, key: RegionKey, *, promote: bool = False, default: Any = None
    ) -> Any:
        """Top-down search; with ``promote`` the hit moves to the top tier."""
        with self._lock:
            for i, t in enumerate(self.tiers):
                try:
                    value = t.get(key)
                except KeyError:
                    continue
                if promote and i > 0:
                    self.promotions += 1
                    n = t.nbytes_of(key) if key in t else sizeof(value)
                    self.promoted_bytes += n
                    evicted = self.tiers[0].put(key, value, n)
                    self._demote_from(0, evicted)
                return value
            return default

    def where(self, key: RegionKey) -> Optional[str]:
        """Name of the fastest tier holding ``key`` (None if absent)."""
        with self._lock:
            for t in self.tiers:
                if key in t:
                    return t.name
            return None

    def nbytes_of(self, key: RegionKey) -> int:
        with self._lock:
            for t in self.tiers:
                if key in t:
                    return t.nbytes_of(key)
            raise KeyError(key)

    def discard(self, key: RegionKey) -> None:
        with self._lock:
            for t in self.tiers:
                t.discard(key)

    def pin(self, key: RegionKey) -> None:
        """Exempt ``key`` from eviction in every tier (live working set)."""
        with self._lock:
            for t in self.tiers:
                t.pin(key)

    def unpin(self, key: RegionKey) -> None:
        with self._lock:
            for t in self.tiers:
                t.unpin(key)

    def __contains__(self, key: RegionKey) -> bool:
        return self.where(key) is not None

    # -- maintenance (StagingAgent hooks) ----------------------------------

    def demote_excess(self, watermark: float = 0.9, batch: int = 8) -> int:
        """Push LRU entries of over-watermark tiers one level down.

        Called by the StagingAgent off the critical path.  The slow part
        — writing into the deeper tier (disk pickling) — runs *outside*
        the store-wide lock so lanes never stall behind a spill; the
        brief window where a moving key is in neither tier is handled by
        callers treating a miss as an eviction (Manager re-pull).
        """
        moved = 0
        for i, t in enumerate(self.tiers[:-1]):
            if not t.over_watermark(watermark):
                continue
            for k in t.lru_keys(batch):
                if t.is_pinned(k):
                    continue
                with self._lock:
                    try:
                        v = t.get(k)
                        n = t.nbytes_of(k)
                    except KeyError:
                        continue
                    t.discard(k)
                if v is None:
                    continue
                evicted = self.tiers[i + 1].put(k, v, n)
                with self._lock:
                    self.demotions += 1
                    self.demoted_bytes += n
                    self._demote_from(i + 1, evicted)
                moved += 1
        return moved

    def stats(self) -> dict[str, dict[str, int]]:
        out = {}
        for t in self.tiers:
            d = t.stats.as_dict()
            d["replicated_evictions"] = t.replicated_evictions
            out[t.name] = d
        out["store"] = {
            "promotions": int(self.promotions),
            "demotions": int(self.demotions),
            "promoted_bytes": int(self.promoted_bytes),
            "demoted_bytes": int(self.demoted_bytes),
            "dropped": int(self.dropped),
        }
        return out
