"""Pluggable storage tiers of the hierarchical region store.

Each tier is a bounded key/value container with LRU discipline and
byte accounting.  The :class:`~repro.staging.store.RegionStore` stacks
tiers fastest-first (device -> host RAM -> local disk -> global store)
and moves regions between them; a tier itself only knows how to hold
data and report what it evicted so the store can demote it.

Tiers never raise on overflow — ``put`` returns the evicted entries —
so a caller can always write and let the hierarchy absorb the spill.
"""

from __future__ import annotations

import hashlib
import os
import pickle
import sys
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Hashable, Iterable, Optional

__all__ = [
    "TierStats",
    "Tier",
    "DeviceTier",
    "HostTier",
    "DiskTier",
    "GlobalTier",
    "sizeof",
]

RegionKey = Hashable


def sizeof(value: Any) -> int:
    """Best-effort byte size of a region payload.

    Understands numpy-like arrays (``nbytes``), containers (recursive),
    and falls back to ``sys.getsizeof``.  Used for tier budgets and the
    placement directory, so only *relative* accuracy matters.
    """
    nbytes = getattr(value, "nbytes", None)
    if isinstance(nbytes, (int, float)):
        return int(nbytes)
    if isinstance(value, dict):
        return sum(sizeof(v) for v in value.values()) or sys.getsizeof(value)
    if isinstance(value, (list, tuple, set, frozenset)):
        return sum(sizeof(v) for v in value) or sys.getsizeof(value)
    if isinstance(value, (bytes, bytearray, memoryview)):
        return len(value)
    try:
        return sys.getsizeof(value)
    except TypeError:  # pragma: no cover - exotic objects
        return 64


@dataclass
class TierStats:
    """Per-tier traffic counters (mirrors SchedulerStats reporting)."""

    puts: int = 0
    gets: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_in: int = 0
    bytes_out: int = 0

    def as_dict(self) -> dict[str, int]:
        return {
            "puts": self.puts,
            "gets": self.gets,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "bytes_in": self.bytes_in,
            "bytes_out": self.bytes_out,
        }


class Tier:
    """LRU key/value tier with a byte budget (``None`` = unbounded)."""

    name = "tier"

    def __init__(self, budget_bytes: Optional[int] = None, name: str | None = None):
        if name is not None:
            self.name = name
        self.budget_bytes = budget_bytes
        self.stats = TierStats()
        self._lock = threading.RLock()
        self._entries: "OrderedDict[RegionKey, tuple[Any, int]]" = OrderedDict()
        self._bytes = 0
        # Pinned keys are never evicted (live working set): the byte
        # budget is a soft cap while consumers are outstanding.
        self._pinned: set[RegionKey] = set()
        # Replication-aware eviction (PlacementPolicy knob): when set
        # (the Manager wires it to PlacementDirectory.replicated_
        # elsewhere), keys whose bytes exist on another worker — or are
        # re-creatable from the global tier — are evicted before sole
        # copies, so budget pressure sheds redundancy first.
        self.replicated: Optional[Callable[[RegionKey], bool]] = None
        self.replicated_evictions = 0

    # -- capacity ----------------------------------------------------------

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return self._bytes

    def over_watermark(self, fraction: float = 0.9) -> bool:
        if self.budget_bytes is None:
            return False
        with self._lock:
            return self._bytes > self.budget_bytes * fraction

    # -- storage -----------------------------------------------------------

    def put(
        self, key: RegionKey, value: Any, nbytes: int | None = None
    ) -> list[tuple[RegionKey, Any, int]]:
        """Insert/refresh ``key``; return entries evicted to make room."""
        nbytes = sizeof(value) if nbytes is None else nbytes
        evicted: list[tuple[RegionKey, Any, int]] = []
        with self._lock:
            if key in self._entries:
                _, old = self._entries.pop(key)
                self._bytes -= old
            self._write(key, value, nbytes)
            self._entries[key] = (self._retain(value), nbytes)
            self._bytes += nbytes
            self.stats.puts += 1
            self.stats.bytes_in += nbytes
            if self.budget_bytes is not None and self._bytes > self.budget_bytes:
                # LRU order with replicated-elsewhere keys first,
                # skipping the new entry and pinned keys.  The victim
                # scan (one ``replicated`` directory probe per entry)
                # only runs once actually over budget.
                for k, repl in self._victim_order(key):
                    if self._bytes <= self.budget_bytes:
                        break
                    v, n = self._entries.pop(k)
                    self._bytes -= n
                    self._erase(k)
                    self.stats.evictions += 1
                    self.stats.bytes_out += n
                    if repl:
                        self.replicated_evictions += 1
                    evicted.append((k, v, n))
        return evicted

    def _victim_order(self, protect: RegionKey):
        """Eviction candidates, oldest-first; with a ``replicated``
        predicate wired, redundant replicas go before sole copies.

        Lazy generator over a snapshot: when freeing the oldest one or
        two replicated entries suffices, only that many directory
        probes are paid (the full scan only happens when eviction must
        fall back to sole copies).
        """
        candidates = [
            k for k in self._entries if k != protect and k not in self._pinned
        ]
        if self.replicated is None:
            for k in candidates:
                yield k, False
            return
        sole: list[RegionKey] = []
        for k in candidates:
            try:
                repl = bool(self.replicated(k))
            except Exception:  # noqa: BLE001 - directory gone: plain LRU
                repl = False
            if repl:
                yield k, True
            else:
                sole.append(k)
        for k in sole:
            yield k, False

    def pin(self, key: RegionKey) -> None:
        with self._lock:
            self._pinned.add(key)

    def unpin(self, key: RegionKey) -> None:
        with self._lock:
            self._pinned.discard(key)

    def is_pinned(self, key: RegionKey) -> bool:
        with self._lock:
            return key in self._pinned

    def get(self, key: RegionKey) -> Any:
        with self._lock:
            self.stats.gets += 1
            if key not in self._entries:
                self.stats.misses += 1
                raise KeyError(key)
            self._entries.move_to_end(key)
            self.stats.hits += 1
            value, nbytes = self._entries[key]
            return self._read(key, value)

    def discard(self, key: RegionKey) -> bool:
        with self._lock:
            entry = self._entries.pop(key, None)
            if entry is None:
                return False
            self._bytes -= entry[1]
            self._erase(key)
            return True

    def nbytes_of(self, key: RegionKey) -> int:
        with self._lock:
            return self._entries[key][1]

    def lru_keys(self, n: int) -> list[RegionKey]:
        """Oldest ``n`` keys — demotion candidates for the StagingAgent."""
        with self._lock:
            return list(self._entries)[:n]

    def __contains__(self, key: RegionKey) -> bool:
        with self._lock:
            return key in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def keys(self) -> list[RegionKey]:
        with self._lock:
            return list(self._entries)

    # -- backend hooks (in-memory by default) ------------------------------

    def _retain(self, value: Any) -> Any:
        """What to keep referenced in RAM; backed tiers return None so
        spilling actually frees memory."""
        return value

    def _write(self, key: RegionKey, value: Any, nbytes: int) -> None:
        pass

    def _read(self, key: RegionKey, value: Any) -> Any:
        return value

    def _erase(self, key: RegionKey) -> None:
        pass


class HostTier(Tier):
    """Host-RAM LRU with a byte budget — the worker's staging heart."""

    name = "host"


class DeviceTier(Tier):
    """Adapter presenting a lane's :class:`DeviceMemory` as a tier.

    The wrapped memory stays the source of truth (the worker's locality
    scheduler reads ``resident_uids`` from it); the tier only adds byte
    accounting and the uniform put/get/evict protocol.  Slot-based LRU
    eviction is delegated to the DeviceMemory itself.
    """

    name = "device"

    def __init__(self, memory: Any, name: str | None = None):
        super().__init__(budget_bytes=None, name=name)
        self.memory = memory

    def put(
        self, key: RegionKey, value: Any, nbytes: int | None = None
    ) -> list[tuple[RegionKey, Any, int]]:
        nbytes = sizeof(value) if nbytes is None else nbytes
        with self._lock:
            before = self.memory.resident_uids()
            self.memory.put(key, value)
            after = self.memory.resident_uids()
            self.stats.puts += 1
            self.stats.bytes_in += nbytes
            self._entries[key] = (None, nbytes)  # bookkeeping only
            evicted = []
            for k in before - after:
                entry = self._entries.pop(k, (None, 0))
                self.stats.evictions += 1
                self.stats.bytes_out += entry[1]
                evicted.append((k, None, entry[1]))
            self._bytes = sum(n for _, n in self._entries.values())
        return evicted

    def get(self, key: RegionKey) -> Any:
        with self._lock:
            self.stats.gets += 1
            if key not in self.memory:
                self.stats.misses += 1
                raise KeyError(key)
            self.stats.hits += 1
            return self.memory.get(key)

    def discard(self, key: RegionKey) -> bool:
        with self._lock:
            self._entries.pop(key, None)
            store = getattr(self.memory, "_store", None)
            if store is not None and key in store:
                del store[key]
                return True
            return False

    def __contains__(self, key: RegionKey) -> bool:
        return key in self.memory


class DiskTier(Tier):
    """Spill directory: regions pickled to local disk, LRU by budget.

    Payloads are NOT kept referenced in RAM (``_retain`` returns None):
    spilling host->disk genuinely frees memory, and every ``get`` is a
    real read-back.  Entries the disk tier itself evicts are gone from
    this node — the store re-reads them from the global tier (or the
    runtime re-executes the chunk).
    """

    name = "disk"

    def __init__(self, directory: str, budget_bytes: Optional[int] = None):
        super().__init__(budget_bytes=budget_bytes)
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _retain(self, value: Any) -> Any:
        return None

    def _path(self, key: RegionKey) -> str:
        # Content-address of the *key*: stable across processes (unlike
        # hash()) and collision-resistant, so a spill directory can be
        # inspected or reused between runs.
        digest = hashlib.sha1(repr(key).encode()).hexdigest()[:24]
        return os.path.join(self.directory, f"region-{digest}.pkl")

    def _write(self, key: RegionKey, value: Any, nbytes: int) -> None:
        with open(self._path(key), "wb") as f:
            pickle.dump(value, f, protocol=pickle.HIGHEST_PROTOCOL)

    def _read(self, key: RegionKey, value: Any) -> Any:
        with open(self._path(key), "rb") as f:
            return pickle.load(f)

    def _erase(self, key: RegionKey) -> None:
        try:
            os.remove(self._path(key))
        except OSError:
            pass


class GlobalTier(Tier):
    """Cluster-global store (models the shared parallel filesystem).

    One instance is shared by every worker's RegionStore in-process; on
    a real deployment this is the Lustre/GPFS-backed object store and
    the tier is a thin client.  Unbounded by default — it is the tier
    of last resort, so dropping from it would lose data.
    """

    name = "global"


def drain(entries: Iterable[tuple[RegionKey, Any, int]]) -> int:
    """Sum the byte sizes of evicted-entry tuples (helper for stats)."""
    return sum(n for _, _, n in entries)
