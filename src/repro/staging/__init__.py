"""Hierarchical data staging: the runtime's storage **tiers** and the
cluster-level placement metadata the data plane routes by.

Regions move through a per-worker tier stack (device memory -> host
RAM -> scratch disk -> global store) driven by a background staging
agent; the Manager-side placement directory (holders + bus addresses
+ rack identity) turns those placements into locality- and rack-aware
lease dispatch, and the write-ahead journal makes that metadata
survive a coordinator restart.  Terminology (control plane / data
plane / tiers) matches ``docs/architecture.md``.

Module map
----------

* :mod:`repro.staging.tiers`     — pluggable storage tiers with LRU +
  byte budgets: ``DeviceTier`` (wraps a lane's ``DeviceMemory``),
  ``HostTier`` (RAM), ``DiskTier`` (local spill), ``GlobalTier``
  (shared cluster store / parallel filesystem model).
* :mod:`repro.staging.store`     — ``RegionStore``: content-addressed
  stack of tiers with promote/demote movement; keys via ``op_key`` /
  ``chunk_key`` / ``content_key``.
* :mod:`repro.staging.agent`     — ``StagingAgent``: per-worker
  background thread that prefetches the inputs of leased-but-unstarted
  stage instances and runs async promote/demote between tiers.
* :mod:`repro.staging.directory` — ``PlacementDirectory``: cluster-wide
  region -> {worker: bytes} metadata the Manager consults at dispatch.
* :mod:`repro.staging.policy`    — ``PlacementPolicy`` /
  ``select_lease``: the locality-aware lease-placement rule with a
  ``transfer_impact``-style tie-break mirroring ``core/scheduling.py``.
* :mod:`repro.staging.config`    — ``StagingConfig``: per-worker tier
  stack construction shared by Worker, Manager, and benchmarks.

How it composes with the paper's runtime: ``core/scheduling.py`` keeps
locality *within* a node (device-memory reuse, §IV-C); this package
lifts the same idea to the cluster — the Manager leases a dependent
stage instance to the worker already holding the largest fraction of
its input bytes, and each worker's StagingAgent hides the residual
transfers behind computation (§IV-D generalized to all tiers).
"""

from .agent import StagingAgent
from .config import StagingConfig
from .directory import PlacementDirectory
from .journal import DirectoryService, WriteAheadJournal
from .policy import PlacementPolicy, select_lease
from .store import RegionStore, chunk_key, content_key, op_key
from .tiers import (
    DeviceTier,
    DiskTier,
    GlobalTier,
    HostTier,
    Tier,
    TierStats,
    sizeof,
)

__all__ = [
    "DeviceTier",
    "DirectoryService",
    "DiskTier",
    "GlobalTier",
    "HostTier",
    "PlacementDirectory",
    "PlacementPolicy",
    "RegionStore",
    "StagingAgent",
    "StagingConfig",
    "Tier",
    "TierStats",
    "WriteAheadJournal",
    "chunk_key",
    "content_key",
    "op_key",
    "select_lease",
    "sizeof",
]
