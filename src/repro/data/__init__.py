"""Data plane: demand-driven chunk leasing + double-buffered loading.

This is the paper's bag-of-tasks Manager applied to the training data
plane: the dataset is an addressable space of idempotent *chunks*
(chunk = pure function of (seed, chunk_id)), a ledger leases chunk
ranges to workers demand-driven with heartbeats and re-leasing, and a
prefetching loader keeps the next batch device-resident while the
current step runs (§IV-D's async copy, host->HBM edition).
"""

from .ledger import ChunkLedger, Lease
from .loader import PrefetchLoader, TokenChunkSource

__all__ = ["ChunkLedger", "Lease", "PrefetchLoader", "TokenChunkSource"]
