"""Chunk sources + double-buffered prefetching loader."""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from .ledger import ChunkLedger
from ..staging import RegionStore, chunk_key

__all__ = ["TokenChunkSource", "PrefetchLoader"]


class TokenChunkSource:
    """Deterministic synthetic LM token chunks.

    chunk_id -> (chunk_tokens, seq_len+1) int32, a pure function of
    (seed, chunk_id): leases are idempotent and re-executable after a
    worker failure, which is what makes the ledger's re-lease safe.
    """

    def __init__(self, vocab: int, seq_len: int, batch_per_chunk: int,
                 seed: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch_per_chunk = batch_per_chunk
        self.seed = seed

    def __call__(self, chunk_id: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.uint64(self.seed) * np.uint64(0x9E3779B9) + np.uint64(chunk_id)
        )
        # Zipfian-ish token stream (more realistic routing/MoE behavior
        # than uniform; deterministic per chunk).
        z = rng.zipf(1.3, size=(self.batch_per_chunk, self.seq_len + 1))
        return (z % self.vocab).astype(np.int32)


class PrefetchLoader:
    """Leases chunks, materializes batches, keeps ``depth`` batches
    device-ready ahead of the consumer (double buffering by default).

    With a ``store`` (hierarchical RegionStore), materialized batches
    are also staged into the host tier under ``chunk_key(cid)``: a
    re-leased chunk (worker failure, epoch replay) is served from the
    tier hierarchy instead of re-materialized, and other components
    (StagingAgent, checkpoint writer) can find the staged bytes.
    """

    def __init__(
        self,
        ledger: ChunkLedger,
        source: Callable[[int], np.ndarray],
        *,
        worker: int = 0,
        lease_block: int = 8,
        depth: int = 2,
        device_put: Optional[Callable[[Any], Any]] = None,
        store: Optional[RegionStore] = None,
    ):
        self.ledger = ledger
        self.source = source
        self.worker = worker
        self.lease_block = lease_block
        self.depth = depth
        self.device_put = device_put or jax.device_put
        self.store = store
        self.store_hits = 0
        self.staged_chunks = 0
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        self.chunks_seen: list[int] = []

    def _materialize(self, cid: int) -> Any:
        if self.store is not None:
            batch = self.store.get(chunk_key(cid), promote=True)
            if batch is not None:
                self.store_hits += 1
                return batch
        arr = self.source(cid)
        batch = self.device_put({"tokens": arr})
        if self.store is not None:
            self.store.put(chunk_key(cid), batch)
            self.staged_chunks += 1
        return batch

    def _fill(self) -> None:
        while not self._stop:
            ids = self.ledger.lease(self.worker, self.lease_block)
            if not ids:
                self._q.put(None)  # epoch exhausted
                return
            for cid in ids:
                if self._stop:
                    return
                batch = self._materialize(cid)
                self._q.put((cid, batch))  # blocks when depth ahead
                self.ledger.heartbeat(self.worker)

    def __iter__(self) -> Iterator[tuple[int, Any]]:
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()
        while True:
            item = self._q.get()
            if item is None:
                return
            cid, batch = item
            self.chunks_seen.append(cid)
            yield cid, batch

    def commit(self, chunk_id: int) -> None:
        self.ledger.commit(self.worker, chunk_id)

    def stop(self) -> None:
        self._stop = True
        if self._thread is not None:
            while not self._q.empty():
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    break
            self._thread.join(timeout=2.0)
