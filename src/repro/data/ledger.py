"""Chunk lease ledger — demand-driven, fault-tolerant, serializable.

The Manager side of the data plane.  Chunks are identified by integer
ids; workers lease blocks of ids, heartbeat while processing, and
commit completions.  Expired leases return to the queue (chunk
generation is idempotent, so re-execution is safe).  The full ledger
state serializes into the training checkpoint so a restart resumes
mid-epoch without repeating or skipping data.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Optional

__all__ = ["Lease", "ChunkLedger"]


@dataclass
class Lease:
    worker: int
    chunks: list[int]
    issued_at: float = field(default_factory=time.monotonic)
    heartbeat: float = field(default_factory=time.monotonic)


class ChunkLedger:
    def __init__(self, n_chunks: int, lease_timeout: float = 30.0):
        self.n_chunks = n_chunks
        self.lease_timeout = lease_timeout
        self._lock = threading.Lock()
        self._next = 0
        self._returned: list[int] = []
        self._completed: set[int] = set()
        self._leases: dict[int, Lease] = {}   # worker -> active lease
        self.releases = 0

    # -- worker API ---------------------------------------------------------

    def lease(self, worker: int, n: int) -> list[int]:
        """Lease up to ``n`` chunk ids (demand-driven)."""
        with self._lock:
            self._reap_locked()
            ids: list[int] = []
            while len(ids) < n and self._returned:
                ids.append(self._returned.pop(0))
            while len(ids) < n and self._next < self.n_chunks:
                ids.append(self._next)
                self._next += 1
            if ids:
                # Store a copy: the caller iterates the returned list
                # while commit() mutates the lease's copy.
                self._leases[worker] = Lease(worker=worker, chunks=list(ids))
            return ids

    def heartbeat(self, worker: int) -> None:
        with self._lock:
            if worker in self._leases:
                self._leases[worker].heartbeat = time.monotonic()

    def commit(self, worker: int, chunk_id: int) -> None:
        with self._lock:
            self._completed.add(chunk_id)
            lease = self._leases.get(worker)
            if lease is not None:
                if chunk_id in lease.chunks:
                    lease.chunks.remove(chunk_id)
                lease.heartbeat = time.monotonic()
                if not lease.chunks:
                    del self._leases[worker]

    def worker_lost(self, worker: int) -> None:
        """Explicit failure notification (elastic scale-down)."""
        with self._lock:
            self._release_locked(worker)

    # -- bookkeeping -------------------------------------------------------------

    def _release_locked(self, worker: int) -> None:
        lease = self._leases.pop(worker, None)
        if lease is not None:
            pending = [c for c in lease.chunks if c not in self._completed]
            self._returned.extend(pending)
            self.releases += len(pending)

    def _reap_locked(self) -> None:
        now = time.monotonic()
        dead = [
            w
            for w, l in self._leases.items()
            if now - l.heartbeat > self.lease_timeout
        ]
        for w in dead:
            self._release_locked(w)

    def done(self) -> bool:
        with self._lock:
            return (
                len(self._completed) >= self.n_chunks
                and not self._returned
                and not self._leases
            )

    def progress(self) -> tuple[int, int]:
        with self._lock:
            return len(self._completed), self.n_chunks

    # -- checkpointing -----------------------------------------------------------

    def state_dict(self) -> dict:
        with self._lock:
            inflight = [
                c
                for l in self._leases.values()
                for c in l.chunks
                if c not in self._completed
            ]
            return {
                "n_chunks": self.n_chunks,
                "next": self._next,
                "returned": sorted(self._returned + inflight),
                "completed": sorted(self._completed),
            }

    @classmethod
    def from_state(cls, state: dict, lease_timeout: float = 30.0) -> "ChunkLedger":
        led = cls(state["n_chunks"], lease_timeout)
        led._next = state["next"]
        led._returned = list(state["returned"])
        led._completed = set(state["completed"])
        return led
