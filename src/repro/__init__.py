"""Reproduction of *High-throughput Execution of Hierarchical Analysis
Pipelines on Hybrid Cluster Platforms* (cs.DC 2012), grown into a
cluster middleware with a real transport, a hierarchical data-staging
subsystem, a network-aware data plane, and a calibrated discrete-event
simulator.

Package map (see ``docs/architecture.md`` for the full picture):

* :mod:`repro.core`      — Manager / Worker runtime, scheduler,
  workflow graphs, calibrated simulator, per-link network model.
* :mod:`repro.transport` — pluggable MessageBus control plane +
  worker-to-worker data plane (Inproc / Socket backends).
* :mod:`repro.staging`   — tiered region stores, staging agents,
  placement directory and locality/rack-aware placement policy.
* :mod:`repro.app`       — the flagship whole-slide-image analysis
  pipeline (segmentation -> feature fan-out).
* :mod:`repro.kernels`   — accelerator kernels (jax/pallas) with CPU
  reference implementations.
"""
