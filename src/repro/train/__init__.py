"""Training substrate: jitted step builders with sharding + donation."""

from .step import TrainState, make_serve_step, make_train_step, make_prefill_step

__all__ = ["TrainState", "make_train_step", "make_serve_step", "make_prefill_step"]
