"""Train / serve step builders.

``make_train_step`` returns a jitted SPMD step with:

* buffer donation (params + opt state update in place),
* optional microbatch gradient accumulation (``lax.scan`` over the
  batch split — activation memory / throughput trade),
* optional int8+error-feedback gradient compression on the DP
  all-reduce (``grad_compression="int8_ef"``): the loss switches to
  per-shard mean (no implicit psum) under ``shard_map`` and the grad
  exchange becomes an explicit quantized collective — 4x fewer bytes
  across the pod interconnect.

``make_serve_step`` / ``make_prefill_step`` build the decode-shape
programs the dry-run lowers for ``decode_*`` / ``prefill_*`` cells.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.model import Model
from ..optim import AdamW, OptState

__all__ = ["TrainState", "make_train_step", "make_serve_step",
           "make_prefill_step"]


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def make_train_step(
    model: Model,
    optimizer: AdamW,
    *,
    microbatches: int = 1,
    remat: bool = True,
    grad_shardings=None,
):
    """-> train_step(state, batch) -> (state, metrics).

    ``grad_shardings``: optional sharding tree for the gradients
    (normally the parameters' storage shardings).  Constraining the
    cotangents right after the backward pass lets GSPMD lower the FSDP
    gradient reduction as reduce-scatter instead of
    all-reduce(+dynamic-slice) — ~(dp-1)/dp fewer wire bytes.
    """

    def loss_fn(params, batch):
        logits, aux = model.train_forward(params, batch, remat=remat)
        tokens = batch.get("tokens")
        if tokens is not None and tokens.ndim == 2:
            labels = tokens[:, 1:]
            lg = logits[:, :-1]
        else:  # embeds-only vlm pretraining: next-embed proxy labels
            labels = jnp.zeros(logits.shape[:2], jnp.int32)[:, 1:]
            lg = logits[:, :-1]
        logp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        loss = nll.mean() + 0.01 * aux
        return loss, {"nll": nll.mean(), "aux": aux}

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
            if grad_shardings is not None:
                grads = jax.tree.map(
                    jax.lax.with_sharding_constraint, grads, grad_shardings
                )
        else:
            split = lambda x: x.reshape(
                microbatches, x.shape[0] // microbatches, *x.shape[1:]
            )
            mb = jax.tree.map(split, batch)

            def acc(carry, b):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(state.params, b)
                return (
                    jax.tree.map(jnp.add, g_acc, g),
                    l_acc + l,
                ), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (grads, loss_sum), _ = jax.lax.scan(acc, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = {"nll": loss, "aux": jnp.zeros(())}
        params, opt = optimizer.update(grads, state.opt, state.params)
        metrics = dict(metrics, loss=loss, step=opt.step)
        return TrainState(params, opt), metrics

    return step


def make_compressed_dp_grads(model: Model, mesh, dp_axes: tuple[str, ...],
                             param_specs_tree):
    """Explicit-DP gradient computation with int8+EF compressed
    all-reduce across ``dp_axes`` (shard_map).  Returns
    ``grads_fn(params, batch, err) -> (grads, new_err, loss)``.

    Parameters must be replicated across ``dp_axes`` for this path
    (pure-DP / TP-only shardings); it exists to cut cross-pod gradient
    bytes, the dominant multi-pod collective.
    """
    from jax.experimental.shard_map import shard_map

    from ..optim.compress import ef_roundtrip

    def local_loss(params, batch):
        logits, aux = model.train_forward(params, batch)
        tokens = batch["tokens"]
        labels = tokens[:, 1:]
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return nll.mean() + 0.01 * aux

    batch_spec = P(dp_axes, None)

    def shard_fn(params, batch, err):
        loss, grads = jax.value_and_grad(local_loss)(params, batch)
        flat, tree = jax.tree.flatten(grads)
        eflat = jax.tree.leaves(err)
        out, new_err = [], []
        for g, e in zip(flat, eflat):
            r, ne = ef_roundtrip(g, e, dp_axes)
            out.append(r)
            new_err.append(ne)
        loss = jax.lax.pmean(loss, dp_axes)
        return jax.tree.unflatten(tree, out), jax.tree.unflatten(tree, new_err), loss

    rep = jax.tree.map(lambda _: P(), param_specs_tree,
                       is_leaf=lambda x: isinstance(x, P))
    return shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(rep, {"tokens": batch_spec}, rep),
        out_specs=(rep, rep, P()),
        check_rep=False,
    )


def make_serve_step(model: Model):
    """-> serve_step(params, caches, tokens, lengths) ->
    (next_tokens, logits, caches, lengths)."""

    def serve_step(params, caches, tokens, lengths):
        logits, caches = model.decode_step(params, caches, tokens, lengths)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, caches, lengths + 1

    return serve_step


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, inputs):
        logits, caches = model.prefill(params, inputs, max_len)
        return logits, caches

    return prefill_step
